"""Serve-subsystem units: admission queue backpressure, schedulers,
bucketed packing, open-loop arrivals, and latency/goodput accounting."""
import math

import pytest

from repro.serve import (AdmissionQueue, Completion, ContinuousBatcher,
                         DeadlineAware, FCFS, OpenLoopSource, Request,
                         ServeMetrics, ShortestJobFirst, default_schemes,
                         make_scheduler, pseudo_poisson_times,
                         substream_seed)


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt
        return self.t


# -- admission queue -----------------------------------------------------------

def test_submit_stamps_arrival_and_fifo_take():
    clock = FakeClock()
    q = AdmissionQueue(clock=clock)
    reqs = []
    for _ in range(3):
        r = Request()
        assert q.submit(r)
        reqs.append(r)
        clock.advance(1.0)
    assert [r.arrival_t for r in reqs] == [0.0, 1.0, 2.0]
    assert q.take(2) == reqs[:2]          # FIFO without a key
    assert len(q) == 1


def test_backpressure_rejects_at_capacity():
    q = AdmissionQueue(depth=2, policy="reject")
    a, b, c = Request(), Request(), Request()
    assert q.submit(a) and q.submit(b)
    assert not q.submit(c)                # full: newcomer refused
    assert c.shed
    stats = q.stats()
    assert stats["rejected"] == 1 and stats["accepted"] == 2
    assert stats["shed_errors"] == 0
    assert q.take(10) == [a, b]           # waiters untouched


def test_shed_oldest_drops_head_and_admits_newcomer():
    q = AdmissionQueue(depth=2, policy="shed-oldest")
    a, b, c = Request(), Request(), Request()
    q.submit(a), q.submit(b)
    assert q.submit(c)                    # admitted by shedding the oldest
    assert a.shed and not c.shed
    assert q.stats()["shed"] == 1
    assert q.take(10) == [b, c]


def test_on_shed_callback_errors_are_counted_not_raised():
    def boom(req):
        raise RuntimeError("shed handler bug")

    q = AdmissionQueue(depth=1, policy="reject", on_shed=boom)
    q.submit(Request())
    assert not q.submit(Request())        # must not raise
    assert q.stats()["shed_errors"] == 1


def test_closed_queue_rejects():
    q = AdmissionQueue()
    q.close()
    assert not q.submit(Request())
    assert q.stats()["rejected"] == 1


def test_take_orders_by_scheduler_key():
    def filled_queue():
        clock = FakeClock()
        q = AdmissionQueue(clock=clock)
        long_ = Request(max_new_tokens=50, prompt_tokens=1)
        short = Request(max_new_tokens=2, prompt_tokens=1)
        urgent = Request(max_new_tokens=20, deadline_s=0.5)
        for r in (long_, short, urgent):
            q.submit(r)
            clock.advance(0.1)
        return q, clock, long_, short, urgent

    # SJF: fewest remaining tokens first.
    q, clock, long_, short, urgent = filled_queue()
    assert q.take(3, key=ShortestJobFirst().key(clock())) == \
        [short, urgent, long_]
    # EDF: explicit deadline outranks the engine-wide default SLO.
    q, clock, long_, short, urgent = filled_queue()
    assert q.take(3, key=DeadlineAware().key(clock(), slo_s=10.0))[0] \
        is urgent
    # FCFS: arrival order.
    q, clock, long_, short, urgent = filled_queue()
    assert q.take(3, key=FCFS().key(clock())) == [long_, short, urgent]


def test_make_scheduler_names():
    assert isinstance(make_scheduler("fcfs"), FCFS)
    assert isinstance(make_scheduler("sjf"), ShortestJobFirst)
    assert isinstance(make_scheduler("deadline"), DeadlineAware)
    with pytest.raises(ValueError):
        make_scheduler("lifo")


# -- open-loop arrivals --------------------------------------------------------

def test_pseudo_poisson_deterministic_and_phased():
    a = pseudo_poisson_times([(1.0, 50.0), (1.0, 200.0)], seed=3)
    b = pseudo_poisson_times([(1.0, 50.0), (1.0, 200.0)], seed=3)
    assert a == b                                     # same seed, same load
    assert a == sorted(a) and a[-1] < 2.0
    lo = sum(1 for t in a if t < 1.0)
    hi = sum(1 for t in a if t >= 1.0)
    assert hi > 2 * lo                                # the ramp ramps


def test_pseudo_poisson_phase_rates_unbiased_at_boundaries():
    # Regression: the sampler used to carry a slow phase's overshoot
    # arrival into the next phase, so a fast phase following a slow one
    # started with an exponential gap drawn at the *slow* rate — shaving
    # a chunk off every fast phase's arrival count.  Each phase must
    # restart memorylessly at its own rate: per-phase counts then track
    # rate * duration, for fast phases preceded by slow ones too.
    phases = [(1.0, 2.0), (1.0, 40.0)] * 50   # slow on even s, fast on odd
    ts = pseudo_poisson_times(phases, seed=11)
    assert ts == sorted(ts) and ts[-1] < 100.0
    slow = sum(1 for t in ts if int(t) % 2 == 0)
    fast = sum(1 for t in ts if int(t) % 2 == 1)
    assert slow == pytest.approx(100, rel=0.35)    # nominal 2 * 50
    assert fast == pytest.approx(2000, rel=0.08)   # nominal 40 * 50
    # every fast phase gets arrivals — the carried-gap bug left phases
    # after a slow stretch starting empty for ~E[slow gap] seconds
    for k in range(1, 100, 2):
        assert any(k <= t < k + 1 for t in ts), f"fast phase {k} empty"


def test_substream_seed_deterministic_per_replica():
    # Same (root, replica) -> same seed; every replica gets a distinct
    # substream, so fleet schedules never replay each other's bursts.
    assert substream_seed(7, 0) == substream_seed(7, 0)
    assert substream_seed(7, "0") == substream_seed(7, "0")
    seeds = {substream_seed(7, i) for i in range(16)}
    assert len(seeds) == 16
    assert substream_seed(7, 1) != substream_seed(8, 1)   # root matters
    # and the substreams drive genuinely different arrival processes:
    a = pseudo_poisson_times([(1.0, 100.0)], seed=substream_seed(3, 1))
    b = pseudo_poisson_times([(1.0, 100.0)], seed=substream_seed(3, 2))
    assert a != b
    assert a == pseudo_poisson_times([(1.0, 100.0)],
                                     seed=substream_seed(3, 1))


def test_open_loop_source_pumps_due_arrivals_only():
    clock = FakeClock()
    q = AdmissionQueue(clock=clock)
    reqs = [Request() for _ in range(3)]
    src = OpenLoopSource(q, [(0.0, reqs[0]), (1.0, reqs[1]), (2.0, reqs[2])])
    assert src.pump(clock()) == 1
    assert src.pump(clock.advance(1.5)) == 1
    assert not src.exhausted
    assert src.next_due(clock()) == pytest.approx(0.5)
    assert src.pump(clock.advance(1.0)) == 1
    assert src.exhausted and src.next_due(clock()) is None
    assert len(q) == 3


# -- batcher -------------------------------------------------------------------

def test_default_schemes_shapes():
    schemes = default_schemes(64)
    assert schemes["single"] == (64,)
    assert schemes["pow2"] == (1, 2, 4, 8, 16, 32, 64)
    assert schemes["coarse"] == (16, 64)


def test_bucket_rounds_up_within_scheme():
    b = ContinuousBatcher(8)              # single/pow2/coarse over cap 8
    assert b.bucket(3, scheme="pow2") == 4
    assert b.bucket(8, scheme="pow2") == 8
    assert b.bucket(1, scheme="single") == 8


def test_scheme_validation():
    with pytest.raises(ValueError):
        ContinuousBatcher(8, schemes={"bad": (4,)})       # doesn't top out
    with pytest.raises(ValueError):
        ContinuousBatcher(8, schemes={"bad": (0, 8)})     # non-positive
    with pytest.raises(ValueError):
        ContinuousBatcher(8, scheme="nope")
    with pytest.raises(ValueError):
        ContinuousBatcher(0)


def test_pack_joins_in_scheduler_order_and_pads():
    clock = FakeClock()
    q = AdmissionQueue(clock=clock)
    short = Request(max_new_tokens=1)
    long_ = Request(max_new_tokens=99)
    q.submit(long_), q.submit(short)
    b = ContinuousBatcher(8, scheme="pow2")
    active = [Request(max_new_tokens=5)]
    batch = b.pack(active, q, ShortestJobFirst(), now=clock.advance(1.0))
    assert batch.requests == [active[0], short, long_]    # SJF joiners
    assert batch.joined == [short, long_]
    assert batch.size == 4 and batch.pad == 1             # 3 rows -> bucket 4
    assert short.service_t == 1.0 and long_.service_t == 1.0
    assert active[0].service_t is None                    # already in flight


def test_pack_respects_batch_cap():
    q = AdmissionQueue()
    for _ in range(10):
        q.submit(Request())
    b = ContinuousBatcher(4, scheme="single")
    batch = b.pack([], q, FCFS(), now=0.0)
    assert len(batch.requests) == 4 and batch.size == 4
    assert len(q) == 6                                    # rest keep waiting


def test_set_scheme_affects_future_packs_only():
    q = AdmissionQueue()
    b = ContinuousBatcher(8)
    b.set_scheme("pow2")
    q.submit(Request())
    first = b.pack([], q, FCFS(), now=0.0)
    assert first.size == 1
    b.set_scheme("single")                                # mid-stream re-tune
    second = b.pack(first.requests, q, FCFS(), now=0.0)
    assert second.requests == first.requests              # nothing dropped
    assert second.size == 8                               # only padding moved
    with pytest.raises(ValueError):
        b.set_scheme("nope")


# -- serve metrics -------------------------------------------------------------

def _completion(arrival, finish, tokens=5, deadline=None, default_slo=1.0):
    req = Request(max_new_tokens=tokens, deadline_s=deadline)
    req.arrival_t, req.service_t = arrival, arrival
    req.first_token_t, req.finish_t = finish, finish
    req.generated = tokens
    return Completion.from_request(req, default_slo_s=default_slo)


def test_metrics_slo_and_goodput_accounting():
    m = ServeMetrics(slo_s=1.0)
    m.observe(_completion(0.0, 0.5, tokens=4))            # within
    m.observe(_completion(0.0, 2.0, tokens=8))            # missed
    m.observe(_completion(0.0, 3.0, tokens=2, deadline=5.0))  # own SLO: ok
    s = m.summary()
    assert s["completed"] == 3 and s["completed_tokens"] == 14
    assert s["slo_met"] == 2 and s["slo_missed"] == 1
    assert s["goodput_tokens"] == 6                       # 4 + 2, not the miss


def test_metrics_percentiles_match_steptimer_convention():
    m = ServeMetrics()
    for latency in (0.1, 0.2, 0.3, 0.4, 1.0):
        m.observe(_completion(0.0, latency, default_slo=None))
    assert m.percentile(50) == pytest.approx(0.3)
    assert m.percentile(99) == pytest.approx(1.0)
    assert math.isnan(ServeMetrics().percentile(95))


def test_interval_goodput_reads_and_resets():
    clock = FakeClock(100.0)
    m = ServeMetrics(slo_s=10.0, clock=clock)
    m.observe(_completion(clock.t, clock.t + 1.0, tokens=30))
    clock.advance(2.0)
    assert m.interval_goodput() == pytest.approx(15.0)
    clock.advance(2.0)
    assert m.interval_goodput() == pytest.approx(0.0)     # window reset


def test_keyed_take_preserves_arrival_order_of_remainder():
    """After a scheduler-keyed take, shed-oldest must still drop the
    longest-waiting request, not whatever the sort left in front."""
    clock = FakeClock()
    q = AdmissionQueue(depth=3, policy="shed-oldest", clock=clock)
    oldest = Request(max_new_tokens=1)        # smallest SJF key, arrives 1st
    mid = Request(max_new_tokens=50)
    newest = Request(max_new_tokens=5)
    for r in (oldest, mid, newest):
        q.submit(r)
        clock.advance(1.0)
    taken = q.take(1, key=ShortestJobFirst().key(clock()))
    assert taken == [oldest]
    q.submit(taken[0])                        # refill to capacity
    overflow = Request(max_new_tokens=9)
    q.submit(overflow)                        # full: head-drop fires
    assert mid.shed                           # longest-waiting went, not SJF order
    assert q.take(10) == [newest, oldest, overflow]
