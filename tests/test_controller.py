"""Controller: the unified per-context explore/exploit driver (online and
offline modes), compile-cost budgeting, warm restarts, and the
ContextualBandit policy."""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ChangeDetector, ContextualBandit, Controller,
                        DEFAULT_CONTEXT, ExhaustiveSweep, IridescentRuntime,
                        Phase, guards)


def _mm_builder(spec):
    B = spec.enum("B", 8, (4, 8, 16))

    def matmul(L, R):
        return (L @ R) * 1.0

    return matmul


def _batch_ctx(args, kwargs):
    return int(args[0].shape[0])


def make_rt(**kw):
    return IridescentRuntime(async_compile=False, **kw)


def _drive(handler, controller, shapes, iters):
    for _ in range(iters):
        for n in shapes:
            handler(jnp.ones((n, n)), jnp.eye(n))
        controller.step()


# --- online, single (default) context ------------------------------------------

def test_controller_explores_and_settles_on_best():
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    h(jnp.ones((4, 4)), jnp.eye(4))
    scores = {4: 1.0, 8: 3.0, 16: 2.0}
    ctl = Controller(
        h, ExhaustiveSweep([{"B": v} for v in (4, 8, 16)]),
        metric=lambda view: scores[view.active_config().get("B")],
        dwell=3, wait_compiles=True)
    _drive(h, ctl, [4], 30)
    assert ctl.settled()
    best, metric = ctl.best()
    assert best == {"B": 8} and metric == 3.0
    assert h.active_config() == {"B": 8}
    # no hand-rolled loop: history carries the full explore trace
    explored = [cfg["B"] for ph, cfg, _ in ctl.history
                if ph is Phase.EXPLORE]
    assert explored == [4, 8, 16]
    rt.shutdown()


def test_controller_change_detection_reexplores():
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    h(jnp.ones((4, 4)), jnp.eye(4))
    phase = {"flip": False}

    def metric(view):
        b = view.active_config().get("B")
        base = {4: 3.0, 8: 2.0, 16: 1.0}[b]
        return (4.0 - base) * 10 if phase["flip"] else base

    ctl = Controller(h, ExhaustiveSweep([{"B": v} for v in (4, 8, 16)]),
                     metric=metric, dwell=2, wait_compiles=True,
                     change_detector=ChangeDetector(0.5, warmup=1))
    _drive(h, ctl, [4], 20)
    assert ctl.settled() and ctl.best()[0] == {"B": 4}
    phase["flip"] = True                     # workload shift inverts ranking
    _drive(h, ctl, [4], 40)
    assert ctl.settled() and ctl.best()[0] == {"B": 16}
    assert ctl.status()[DEFAULT_CONTEXT]["explorations"] >= 2
    rt.shutdown()


def test_controller_warm_restart_starts_in_exploit():
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    h(jnp.ones((4, 4)), jnp.eye(4))
    ctl = Controller(h, ExhaustiveSweep([{"B": v} for v in (4, 8, 16)]),
                     dwell=3, wait_compiles=True,
                     initial_configs={DEFAULT_CONTEXT: {"B": 16}})
    _drive(h, ctl, [4], 2)
    assert ctl.settled()
    assert h.active_config() == {"B": 16}
    # no exploration happened: the restored config went straight to EXPLOIT
    assert all(ph is Phase.EXPLOIT for ph, _, _ in ctl.history)
    rt.shutdown()


# --- online, multiple contexts --------------------------------------------------

def test_two_contexts_settle_on_different_configs():
    """The mixed-batch serve story: per-context search converges to a
    different winner per batch-shape class (deterministic metric table)."""
    rt = make_rt()
    h = rt.register("m", _mm_builder, context_fn=_batch_ctx)
    scores = {(4, 4): 9.0, (4, 8): 1.0, (4, 16): 1.0,
              (8, 4): 1.0, (8, 8): 2.0, (8, 16): 7.0}

    ctl = Controller(
        h, lambda: ExhaustiveSweep([{"B": v} for v in (4, 8, 16)]),
        metric=lambda view: scores[(view.key,
                                    view.active_config().get("B"))],
        dwell=2, wait_compiles=True)
    _drive(h, ctl, [4, 8], 30)
    assert ctl.settled()
    assert h.active_config(context=4) == {"B": 4}
    assert h.active_config(context=8) == {"B": 16}
    assert ctl.best_configs() == {4: {"B": 4}, 8: {"B": 16}}
    rt.shutdown()


def test_contexts_admitted_only_with_traffic():
    rt = make_rt()
    h = rt.register("m", _mm_builder, context_fn=_batch_ctx)
    ctl = Controller(h, lambda: ExhaustiveSweep([{"B": 4}]), dwell=2,
                     wait_compiles=True)
    _drive(h, ctl, [4], 10)
    # the default context exists on the handler but received no traffic:
    # the controller must not explore it
    assert DEFAULT_CONTEXT in h.contexts()
    assert ctl.contexts() == [4]
    rt.shutdown()


def test_per_context_policies_are_independent():
    """Observations in one context never leak into another's policy."""
    rt = make_rt()
    h = rt.register("m", _mm_builder, context_fn=_batch_ctx)
    pols = []

    def factory():
        p = ExhaustiveSweep([{"B": v} for v in (4, 8)])
        pols.append(p)
        return p

    ctl = Controller(h, factory, metric=lambda view: 1.0, dwell=2,
                     wait_compiles=True)
    _drive(h, ctl, [4, 8], 15)
    assert len(pols) == 2                    # one fresh policy per context
    rt.shutdown()


# --- compile-cost budgeting -----------------------------------------------------

def test_budget_skips_expensive_candidates():
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    h(jnp.ones((4, 4)), jnp.eye(4))
    costs = {4: 0.0, 8: 1e6, 16: 0.0}        # candidate B=8 is "huge"
    scores = {4: 1.0, 8: 50.0, 16: 2.0}
    ctl = Controller(
        h, ExhaustiveSweep([{"B": v} for v in (4, 8, 16)]),
        metric=lambda view: scores[view.active_config().get("B")],
        dwell=2, wait_compiles=True, budget=1.0,
        cost_fn=lambda cfg: costs[cfg["B"]])
    _drive(h, ctl, [4], 30)
    assert ctl.settled()
    explored = {cfg["B"] for ph, cfg, _ in ctl.history
                if ph is Phase.EXPLORE}
    assert 8 not in explored                 # skipped: cost >> dwell gain
    assert ctl.status()[DEFAULT_CONTEXT]["skipped"] >= 1
    assert ctl.best()[0] == {"B": 16}
    rt.shutdown()


def test_budget_never_skips_already_built_variants():
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    h(jnp.ones((4, 4)), jnp.eye(4))
    h.specialize({"B": 8}, wait=True)        # variant already exists
    ctl = Controller(
        h, ExhaustiveSweep([{"B": 8}]),
        metric=lambda view: 1.0, dwell=2, wait_compiles=True, budget=0.001,
        cost_fn=lambda cfg: 1e9)
    _drive(h, ctl, [4], 10)
    explored = [cfg["B"] for ph, cfg, _ in ctl.history
                if ph is Phase.EXPLORE]
    assert explored == [8]                   # marginal cost ~0: not skipped
    rt.shutdown()


def test_budget_skipped_candidates_never_elected():
    """Once a dwell-time basis exists, every over-budget candidate is
    skipped, never observed, and can never become the EXPLOIT winner; the
    gate is inactive for the very first candidate (no basis to weigh cost
    against yet), which therefore explores normally."""
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    h(jnp.ones((4, 4)), jnp.eye(4))
    ctl = Controller(
        h, ExhaustiveSweep([{"B": v} for v in (4, 8, 16)]),
        metric=lambda view: 1.0, dwell=2, wait_compiles=True, budget=0.001,
        cost_fn=lambda cfg: 1e9)
    _drive(h, ctl, [4], 10)
    assert ctl.settled()
    explored = [cfg["B"] for ph, cfg, _ in ctl.history
                if ph is Phase.EXPLORE]
    assert explored == [4]                         # only the ungated first
    assert ctl.status()[DEFAULT_CONTEXT]["skipped"] == 2
    assert h.active_config() == {"B": 4}           # never a skipped config
    rt.shutdown()


def test_budget_skip_does_not_abort_bandit_exploration():
    """A bandit re-proposes an unpulled arm until it is observed; one
    over-budget arm must not abort exploration of the remaining arms
    (regression: the gate used to force EXPLOIT with best=None)."""
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    h(jnp.ones((4, 4)), jnp.eye(4))
    costs = {4: 1e9, 8: 0.0, 16: 0.0}        # the FIRST arm is over budget
    scores = {4: 50.0, 8: 1.0, 16: 3.0}
    ctl = Controller(
        h, ContextualBandit([{"B": v} for v in (4, 8, 16)], rounds=8),
        metric=lambda view: scores[view.active_config().get("B")],
        dwell=2, wait_compiles=True, budget=1.0,
        cost_fn=lambda cfg: costs[cfg["B"]],
        sec_per_call_prior=0.001)            # gate active from candidate 1
    _drive(h, ctl, [4], 40)
    assert ctl.settled()
    explored = {cfg["B"] for ph, cfg, _ in ctl.history
                if ph is Phase.EXPLORE}
    assert explored == {8, 16}               # cheap arms all measured
    assert ctl.best()[0] == {"B": 16}        # vetoed arm never elected
    assert h.active_config() == {"B": 16}
    rt.shutdown()


def test_unknown_spec_state_version_not_misparsed(tmp_path):
    """A future-versioned spec_state.json must be refused loudly, not
    silently misread as the v1 flat format."""
    import json as _json
    from repro.checkpoint import restore_spec_state
    path = str(tmp_path / "spec_state.json")
    with open(path, "w") as f:
        _json.dump({"version": 99, "handlers": {"m": {"contexts": {}}}}, f)
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    assert restore_spec_state(path, rt, wait=True) is False
    assert h.active_config() == {}
    rt.shutdown()


def test_stale_restored_config_falls_back_to_exploration():
    """A warm-start config that is no longer valid (points renamed /
    choices changed) must not crash step(); the context explores fresh."""
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    h(jnp.ones((4, 4)), jnp.eye(4))
    ctl = Controller(h, ExhaustiveSweep([{"B": 4}]),
                     metric=lambda view: 1.0, dwell=2, wait_compiles=True,
                     initial_configs={DEFAULT_CONTEXT: {"gone_point": 1}})
    _drive(h, ctl, [4], 10)                        # must not raise
    assert ctl.settled()
    assert h.active_config() == {"B": 4}           # fresh exploration won
    rt.shutdown()


# --- offline mode ---------------------------------------------------------------

def test_offline_run_drives_policy_to_best():
    ctl = Controller(policy=ExhaustiveSweep([{"k": i} for i in range(6)]),
                     measure=lambda cfg: -abs(cfg["k"] - 4))
    best, metric = ctl.run()
    assert best == {"k": 4} and metric == 0
    assert len(ctl.history) == 6             # every candidate measured once


def test_offline_controller_rejects_step_and_vice_versa():
    ctl = Controller(policy=ExhaustiveSweep([{"k": 1}]),
                     measure=lambda cfg: 0.0)
    with pytest.raises(RuntimeError):
        ctl.step()
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    online = Controller(h, ExhaustiveSweep([{"B": 4}]))
    with pytest.raises(RuntimeError):
        online.run()
    rt.shutdown()


# --- ContextualBandit -----------------------------------------------------------

def test_bandit_pulls_every_arm_then_exploits_best():
    bd = ContextualBandit([{"x": i} for i in range(4)], rounds=20)
    seen = []
    while True:
        cfg = bd.propose()
        if cfg is None:
            break
        seen.append(cfg["x"])
        bd.observe(cfg, float(cfg["x"] == 2))
    assert sorted(set(seen[:4])) == [0, 1, 2, 3]   # each arm pulled once
    assert seen.count(2) > len(seen) / 3           # best arm dominates
    best, mean = bd.best()
    assert best == {"x": 2} and mean == 1.0


def test_bandit_auto_rounds_and_reset():
    bd = ContextualBandit([{"x": 0}, {"x": 1}])
    assert bd.rounds == 8                          # 4 pulls per arm
    n = 0
    while bd.propose() is not None:
        n += 1
        bd.observe({"x": 0}, 1.0)
    assert n == 8
    bd.reset()
    assert bd.propose() is not None                # fresh arm statistics


def test_bandit_tie_breaks_to_earliest_candidate():
    bd = ContextualBandit([{"x": "a"}, {"x": "b"}], rounds=4)
    bd.observe({"x": "a"}, 1.0)
    bd.observe({"x": "b"}, 1.0)
    assert bd.best()[0] == {"x": "a"}


def test_bandit_with_controller_per_context_arm_sets():
    """One bandit per context: each workload class converges to its own
    arm under a deterministic per-context reward table."""
    rt = make_rt()
    h = rt.register("m", _mm_builder, context_fn=_batch_ctx)
    reward = {(4, 4): 5.0, (4, 8): 1.0, (8, 4): 1.0, (8, 8): 5.0,
              (4, 16): 0.5, (8, 16): 0.5}
    ctl = Controller(
        h, lambda: ContextualBandit([{"B": v} for v in (4, 8, 16)],
                                    rounds=9),
        metric=lambda view: reward[(view.key,
                                    view.active_config().get("B"))],
        dwell=2, wait_compiles=True)
    _drive(h, ctl, [4, 8], 40)
    assert ctl.settled()
    assert h.active_config(context=4) == {"B": 4}
    assert h.active_config(context=8) == {"B": 8}
    rt.shutdown()


def test_controller_accepts_thompson_sampling_per_context():
    """ROADMAP satellite: the Controller runs a ThompsonSampling policy —
    one independent posterior per specialization context."""
    from repro.core import ThompsonSampling
    rt = make_rt()
    h = rt.register("m", _mm_builder, context_fn=_batch_ctx)
    h(jnp.ones((4, 4)), jnp.eye(4))
    h(jnp.ones((8, 8)), jnp.eye(8))
    scores = {4: {4: 3.0, 8: 1.0}, 8: {4: 1.0, 8: 3.0}}

    def metric(view):
        return scores[view.key][view.active_config().get("B")]

    ctl = Controller(
        h, ThompsonSampling([{"B": 4}, {"B": 8}], seed=5, rounds=8),
        metric=metric, dwell=2, wait_compiles=True,
        change_detector=lambda: ChangeDetector(float("inf")))
    _drive(h, ctl, [4, 8], 40)
    assert ctl.settled()
    assert ctl.best(context=4)[0] == {"B": 4}
    assert ctl.best(context=8)[0] == {"B": 8}
    # the per-context policies are independent instances with own state
    ctls = ctl._ctls
    assert ctls[4].policy is not ctls[8].policy
    assert h.active_config(context=4) == {"B": 4}
    assert h.active_config(context=8) == {"B": 8}
    rt.shutdown()
