"""Fleet serving: routing policies, the shared specialization plane
(publish/subscribe, conflict resolution, crash tolerance), cross-replica
warm starts with zero recompiles, and fleet-level metric aggregation."""
import json
import os

import jax.numpy as jnp
import pytest

from repro.checkpoint import (PLANE_RECORD_VERSION, load_plane_record,
                              save_plane_record)
from repro.core import (Controller, ExhaustiveSweep, IridescentRuntime,
                        VariantCache)
from repro.core.runtime import encode_context_key
from repro.serve import Completion, Request, ServeMetrics
from repro.serve.fleet import (DeadlineSpill, JoinShortestQueue,
                               ReplicaRouter, RoundRobin, SpecPlane,
                               make_routing_policy)


class FakeReplica:
    def __init__(self, depth=0, accept=True):
        self._depth = depth
        self.accept = accept
        self.got = []

    def submit(self, request):
        self.got.append(request)
        return self.accept

    def depth(self):
        return self._depth


# -- routing policies ----------------------------------------------------------

def test_round_robin_cycles_fairly():
    reps = [FakeReplica() for _ in range(3)]
    router = ReplicaRouter(reps, policy="round-robin")
    for _ in range(6):
        assert router.submit(Request())
    assert [len(r.got) for r in reps] == [2, 2, 2]
    assert router.routed == [2, 2, 2] and router.refused == [0, 0, 0]


def test_jsq_picks_reported_min_depth():
    reps = [FakeReplica(depth=5), FakeReplica(depth=1), FakeReplica(depth=3)]
    router = ReplicaRouter(reps, policy="jsq")
    router.submit(Request())
    assert len(reps[1].got) == 1
    # ties break to the lowest index — deterministic under equal load
    reps[0]._depth = reps[2]._depth = 1
    router.submit(Request())
    assert len(reps[0].got) == 1


def test_spill_keeps_home_until_deadline_threatened():
    reps = [FakeReplica(depth=0), FakeReplica(depth=0)]
    router = ReplicaRouter(reps, policy="spill", est_wait_s=0.1, margin=0.5)
    router.submit(Request(deadline_s=10.0))       # home 0, not overloaded
    router.submit(Request(deadline_s=10.0))       # home 1
    assert [len(r.got) for r in reps] == [1, 1]
    # home 0 now backlogged enough to blow a tight deadline: spill to 1
    reps[0]._depth = 50
    router.submit(Request(deadline_s=1.0))
    assert len(reps[1].got) == 2
    assert router.policy.spills == 1
    assert router.stats()["spills"] == 1


def test_spill_deadline_less_uses_max_depth():
    reps = [FakeReplica(depth=40), FakeReplica(depth=0)]
    pol = DeadlineSpill(max_depth=32)
    router = ReplicaRouter(reps, policy=pol)
    router.submit(Request())                      # home 0 over max_depth
    assert len(reps[1].got) == 1 and pol.spills == 1


def test_router_counts_refusals_never_retries():
    reps = [FakeReplica(accept=False), FakeReplica()]
    router = ReplicaRouter(reps, policy="round-robin")
    assert router.submit(Request()) is False      # landed on the refuser
    assert router.submit(Request()) is True
    assert router.refused == [1, 0]
    assert len(reps[0].got) == 1                  # offered once, open-loop


def test_router_validation_and_policy_factory():
    with pytest.raises(ValueError):
        ReplicaRouter([])
    with pytest.raises(ValueError):
        make_routing_policy("power-of-two")
    assert isinstance(make_routing_policy("round-robin"), RoundRobin)
    assert isinstance(make_routing_policy("jsq"), JoinShortestQueue)


# -- plane records -------------------------------------------------------------

def _record(path, **kw):
    defaults = dict(handler="h", context=encode_context_key(4),
                    config={"fused": True}, goodput=1.0, epoch=1,
                    replica="1", t=0.0)
    defaults.update(kw)
    save_plane_record(str(path), **defaults)
    return str(path)


def test_plane_record_round_trip(tmp_path):
    p = _record(tmp_path / "r.json", goodput=2.5, epoch=3)
    with open(p) as f:
        assert json.load(f)["version"] == PLANE_RECORD_VERSION  # wire format
    rec = load_plane_record(p)
    assert rec["config"] == {"fused": True}
    assert (rec["goodput"], rec["epoch"], rec["replica"]) == (2.5, 3, "1")


@pytest.mark.parametrize("payload", [
    b"",                                          # truncated to nothing
    b'{"version": 1, "handler"',                  # torn mid-write
    b"\x00\xffnot json",                          # binary garbage
    json.dumps({"version": 999}).encode(),        # unknown version
    json.dumps([1, 2, 3]).encode(),               # not a record
    json.dumps({"version": 1, "handler": "h"}).encode(),  # fields missing
])
def test_plane_ignores_bad_records(tmp_path, payload):
    bad = tmp_path / "bad.json"
    bad.write_bytes(payload)
    assert load_plane_record(str(bad)) is None
    _record(tmp_path / "good.json")
    plane = SpecPlane(str(tmp_path), replica="me")
    winners = plane.resolve()                     # bad record never fatal
    assert list(winners) == [("h", encode_context_key(4))]


def test_plane_conflict_resolution_rank(tmp_path):
    plane = SpecPlane(str(tmp_path), replica="me")
    a = SpecPlane(str(tmp_path), replica="a")
    b = SpecPlane(str(tmp_path), replica="b")
    # freshest epoch wins regardless of goodput
    a.publish("h", 4, {"fused": True}, goodput=9.0, epoch=1)
    b.publish("h", 4, {"fused": False}, goodput=0.1, epoch=2)
    winner = plane.resolve()[("h", encode_context_key(4))]
    assert winner["replica"] == "b" and winner["config"] == {"fused": False}
    # equal epochs: goodput evidence breaks the tie
    a.publish("h", 8, {"fused": True}, goodput=5.0, epoch=7)
    b.publish("h", 8, {"fused": False}, goodput=3.0, epoch=7)
    assert plane.resolve()[("h", encode_context_key(8))]["replica"] == "a"
    # full tie: replica id keeps it deterministic fleet-wide
    a.publish("h", 16, {"fused": True}, goodput=1.0, epoch=1)
    b.publish("h", 16, {"fused": True}, goodput=1.0, epoch=1)
    assert plane.resolve()[("h", encode_context_key(16))]["replica"] == "b"


def test_plane_publish_after_poll_supersedes(tmp_path):
    # The Lamport property: a replica that has *seen* epoch N publishes at
    # N+1, so its update wins the next resolution everywhere.
    a = SpecPlane(str(tmp_path), replica="a")
    b = SpecPlane(str(tmp_path), replica="b")
    a.publish("h", 4, {"fused": True}, goodput=1.0)
    b.resolve()
    b.publish("h", 4, {"fused": False}, goodput=0.5)
    winner = a.resolve()[("h", encode_context_key(4))]
    assert winner["replica"] == "b" and winner["epoch"] == 2


class FakeHandler:
    def __init__(self, fail=False):
        self.fail = fail
        self.seeded = []

    def seed_spec_state(self, enc, cfg):
        if self.fail:
            raise ValueError("stale config")
        self.seeded.append((enc, dict(cfg)))


class FakeRuntime:
    def __init__(self, **handlers):
        self.handlers = handlers


def test_plane_poll_seeds_remote_winners_once(tmp_path):
    a = SpecPlane(str(tmp_path), replica="a")
    b = SpecPlane(str(tmp_path), replica="b")
    a.publish("h", 4, {"fused": True}, goodput=1.0)
    h = FakeHandler()
    rt = FakeRuntime(h=h)
    b.poll(rt)
    assert h.seeded == [(encode_context_key(4), {"fused": True})]
    b.poll(rt)                                    # idempotent: same winner
    assert len(h.seeded) == 1
    a.publish("h", 4, {"fused": False}, goodput=2.0)
    b.poll(rt)                                    # fresher record re-seeds
    assert h.seeded[-1] == (encode_context_key(4), {"fused": False})
    # a's own records never loop back onto a
    own = FakeHandler()
    a.poll(FakeRuntime(h=own))
    assert own.seeded == []


def test_plane_poll_tolerates_seed_failure_and_unknown_handler(tmp_path):
    a = SpecPlane(str(tmp_path), replica="a")
    a.publish("h", 4, {"fused": True}, goodput=1.0)
    a.publish("ghost", 4, {"fused": True}, goodput=1.0)
    bad = FakeHandler(fail=True)
    b = SpecPlane(str(tmp_path), replica="b")
    b.poll(FakeRuntime(h=bad))                    # raises inside: swallowed
    assert bad.seeded == []
    bad.fail = False
    b.poll(FakeRuntime(h=bad))                    # not marked applied: retried
    assert len(bad.seeded) == 1


def test_plane_publish_controller_skips_unchanged(tmp_path):
    class FakeCtl:
        def __init__(self, winners):
            self.winners = winners

        def settled_winners(self):
            return self.winners

    plane = SpecPlane(str(tmp_path), replica="a")
    ctl = FakeCtl({4: ({"fused": True}, 2.0)})
    assert plane.publish_controller("h", ctl) == 1
    assert plane.publish_controller("h", ctl) == 0    # unchanged: no churn
    ctl.winners = {4: ({"fused": False}, 3.0)}
    assert plane.publish_controller("h", ctl) == 1


# -- warm start round trip -----------------------------------------------------

def _fused_builder(spec):
    fused = spec.enum("fused", False, (False, True), guarded=False)

    def f(x, w):
        if fused:
            return x @ w
        h = w.shape[1] // 2
        return jnp.concatenate([x @ w[:, :h], x @ w[:, h:]], axis=-1)

    return f


def test_plane_round_trip_warm_start_zero_recompiles(tmp_path):
    """The acceptance chain: replica 1 explores, publishes its settled
    winner; replica 2 (sharing a *portable* variant cache) polls, is
    seeded, and activates the winner as a cache hit — zero XLA compiles,
    and its Controller admits the context directly settled."""
    cache_dir = str(tmp_path / "variants")
    plane_dir = str(tmp_path / "plane")
    ctx_fn = lambda a, k: int(a[0].shape[0])  # noqa: E731
    x, w = jnp.ones((4, 8)), jnp.ones((8, 8))

    rt1 = IridescentRuntime(async_compile=False,
                            variant_cache=VariantCache(cache_dir,
                                                       portable=True))
    h1 = rt1.register("step", _fused_builder, context_fn=ctx_fn)
    ctl1 = Controller(
        h1, lambda: ExhaustiveSweep([{"fused": True}, {"fused": False}]),
        metric=lambda view: 2.0 if view.active_config()["fused"] else 1.0,
        dwell=2, wait_compiles=True)
    for _ in range(30):
        h1(x, w)
        ctl1.step()
        if ctl1.settled():
            break
    assert ctl1.settled()
    winners = ctl1.settled_winners()
    assert winners[4][0] == {"fused": True}
    plane1 = SpecPlane(plane_dir, replica="1")
    assert plane1.publish_controller("step", ctl1) == 1
    assert rt1.compile_stats()["xla_compiles"] > 0    # replica 1 paid
    rt1.shutdown()

    rt2 = IridescentRuntime(async_compile=False,
                            variant_cache=VariantCache(cache_dir,
                                                       portable=True))
    h2 = rt2.register("step", _fused_builder, context_fn=ctx_fn)
    ctl2 = Controller(
        h2, lambda: ExhaustiveSweep([{"fused": True}, {"fused": False}]),
        metric=lambda view: 1.0, dwell=2, wait_compiles=True)
    SpecPlane(plane_dir, replica="2").poll(rt2)
    h2(x, w)
    ctl2.step()
    stats = rt2.compile_stats()
    assert stats["xla_compiles"] == 0                 # compile-free
    assert stats["cache_hits"] >= 1
    assert h2.active_config(context=4) == {"fused": True}
    assert ctl2.settled()                             # admitted in EXPLOIT
    rt2.shutdown()


# -- fleet metric aggregation --------------------------------------------------

def _completion(latency, tokens=4, within=True):
    return Completion(rid=0, prompt_tokens=2, tokens=tokens, arrival_t=0.0,
                      service_t=latency / 2, first_token_t=latency / 2,
                      finish_t=latency, within_slo=within)


def test_metrics_state_round_trip():
    m = ServeMetrics(slo_s=0.5)
    m.observe(_completion(0.1))
    m.observe(_completion(0.9, within=False))
    m.observe_shed(3)
    back = ServeMetrics.from_state(m.state())
    assert back.completed == 2 and back.shed == 3
    assert back.goodput_tokens == 4 and back.completed_tokens == 8
    assert back.slo_s == 0.5
    assert back.percentile(50) == m.percentile(50)
    # state() is JSON-portable: the worker ships it over a pipe
    wire = json.loads(json.dumps(m.state()))
    assert ServeMetrics.from_state(wire).completed == 2


def test_metrics_merge_counters_and_rank_percentiles():
    a, b = ServeMetrics(slo_s=0.5), ServeMetrics(slo_s=0.5)
    for lat in (0.1, 0.2, 0.3):
        a.observe(_completion(lat))
    for lat in (0.4, 0.5, 0.6):
        b.observe(_completion(lat, within=False))
    merged = ServeMetrics.merge(a, b)
    assert merged.completed == 6
    assert merged.goodput_tokens == 12 and merged.completed_tokens == 24
    assert merged.slo_met == 3 and merged.slo_missed == 3
    # nearest-rank over the *combined* samples, not averaged percentiles
    assert merged.percentile(50) == pytest.approx(0.3)
    assert merged.percentile(99) == pytest.approx(0.6)
    # instances and state() snapshots mix freely (the fleet front merges
    # wire snapshots from subprocess replicas)
    assert ServeMetrics.merge(a, b.state()).completed == 6
    # slo_s survives only under fleet-wide agreement
    c = ServeMetrics(slo_s=9.9)
    assert ServeMetrics.merge(a, c).slo_s is None
    assert ServeMetrics.merge(a, b).slo_s == 0.5


def test_metrics_merge_empty_and_single():
    assert ServeMetrics.merge().completed == 0
    m = ServeMetrics()
    m.observe(_completion(0.2))
    assert ServeMetrics.merge(m).completed == 1


def test_metrics_buffers_bounded_on_long_streams():
    """Regression (ISSUE 9 satellite): sample buffers are reservoirs — a
    long-lived server never grows them past ``window``, and percentiles
    stay nearest-rank over a uniform sample of the whole stream."""
    m = ServeMetrics(slo_s=10.0, window=64)
    for i in range(10_000):
        m.observe(_completion(0.001 * (i % 100 + 1)))
    assert len(m._latencies) == 64
    assert m._latencies.seen == 10_000
    assert m.completed == 10_000          # counters are exact, not sampled
    # the retained sample spans the stream's range, not just its head
    assert 0.0 < m.percentile(50) <= 0.1
    st = m.state()
    assert len(st["latencies"]) == 64 and st["latencies_seen"] == 10_000


def test_metrics_merge_stays_bounded():
    parts = []
    for r in range(8):
        m = ServeMetrics(slo_s=1.0, window=2048)
        for i in range(1000):
            m.observe(_completion(0.01))
        parts.append(m)
    merged = ServeMetrics.merge(*parts)
    assert merged.completed == 8000
    assert len(merged._latencies) <= merged.window
    assert merged._latencies.seen == 8000
    # merging merges never compounds the window either
    again = ServeMetrics.merge(merged, merged)
    assert len(again._latencies) <= again.window
    assert again._latencies.seen == 16_000


def test_metrics_from_state_accepts_pre_reservoir_wire_format():
    # older snapshots carry no *_seen fields: seen defaults to len(samples)
    wire = {"slo_s": 0.5, "latencies": [0.1, 0.2], "completed": 2}
    back = ServeMetrics.from_state(wire)
    assert back._latencies.seen == 2
    assert back.percentile(50) == pytest.approx(0.1)


# -- subprocess worker ---------------------------------------------------------

def test_subprocess_worker_round_trip(tmp_path):
    """One synthetic worker behind the stdio protocol: ready, serves a
    routed schedule, reports depth, exits with mergeable stats."""
    from repro.serve.fleet.worker import SubprocessReplica, worker_command

    rep = SubprocessReplica(
        worker_command("--profile", "synthetic", "--replica-id", "w",
                       "--d", "64", "--dwell", "2", "--max-wall-s", "60"),
        name="w")
    try:
        assert rep.wait_ready(300.0)
        router = ReplicaRouter([rep], policy="round-robin")
        for _ in range(6):
            assert router.submit(Request(prompt_tokens=4, max_new_tokens=2))
    finally:
        rep.close()
        stats = rep.join(300.0)
    assert stats is not None and stats["replica"] == "w"
    merged = ServeMetrics.merge(stats["metrics"])
    assert merged.completed == 6
    assert stats["compile"]["xla_compiles"] > 0       # cold: no shared cache
    assert stats["settled"]                           # winners reported
