"""Backend-portable kernel registry: listing, availability filtering on a
CPU-only host, the ``{family}_impl`` spec point round-tripping through
``Handler.specialize``, and guard-miss / unavailability fallback to
``xla_ref``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import ExhaustiveSweep, Explorer, IridescentRuntime, Phase
from repro.kernels import matmul, registry, rmsnorm
from repro.kernels.registry import (FALLBACK_IMPL, KernelRegistry,
                                    canonical_name, impl_point)

FAMILIES = ("matmul", "attention", "rmsnorm", "linear_attention", "fastpath")


# -- listing & availability -------------------------------------------------------

def test_all_families_registered_with_fallback():
    fams = registry.families()
    for family in FAMILIES:
        assert family in fams
        impls = registry.implementations(family)
        assert FALLBACK_IMPL in impls, family
        assert "pallas_tpu" in impls, family


def test_cpu_availability_filtering():
    # this suite pins JAX_PLATFORMS=cpu: TPU/GPU-only entries must be
    # filtered out of the candidate set, xla_ref must always survive.
    for family in FAMILIES:
        names = registry.choices(family)
        assert FALLBACK_IMPL in names, family
        assert "pallas_tpu" not in names, family
        assert "pallas_gpu" not in names, family
    assert registry.get("matmul", "pallas_tpu").is_available() is False


def test_auto_resolution_prefers_xla_ref_on_cpu():
    # xla_ref (priority 0) outranks pallas_interpret (negative priority)
    for family in FAMILIES:
        assert registry.resolve(family, None).name == FALLBACK_IMPL
        assert registry.resolve(family, "auto").name == FALLBACK_IMPL


def test_legacy_alias_names_accepted():
    assert canonical_name("xla") == "xla_ref"
    assert canonical_name("interpret") == "pallas_interpret"
    assert canonical_name("pallas") == "pallas_tpu"
    assert registry.get("rmsnorm", "xla").name == "xla_ref"
    x = jnp.ones((8, 16), jnp.float32)
    w = jnp.ones((16,), jnp.float32)
    np.testing.assert_allclose(rmsnorm.rmsnorm(x, w, impl="xla"),
                               rmsnorm.rmsnorm(x, w, impl="xla_ref"))


def test_unknown_names_raise():
    with pytest.raises(KeyError):
        registry.get("matmul", "no_such_impl")
    with pytest.raises(KeyError):
        registry.resolve("no_such_family", None)


# -- fallback semantics -----------------------------------------------------------

def test_unavailable_named_impl_falls_back_to_xla_ref():
    # pallas_tpu cannot run on this host; dispatch must produce the
    # reference result instead of crashing.
    x = jnp.asarray(np.random.RandomState(0).randn(16, 8), jnp.float32)
    y = jnp.asarray(np.random.RandomState(1).randn(8, 12), jnp.float32)
    out = matmul.matmul(x, y, impl="pallas_tpu")
    np.testing.assert_allclose(out, matmul.matmul(x, y, impl="xla_ref"),
                               rtol=1e-6, atol=1e-6)


def test_guard_miss_falls_back_to_xla_ref():
    reg = KernelRegistry()

    @reg.register("toy", "xla_ref")
    def _ref(x):
        return x + 1

    @reg.register("toy", "fancy", priority=10,
                  guard=lambda x: x.shape[0] % 2 == 0)
    def _fancy(x):
        return x * 0 - 999          # wrong on purpose: must not run on odd

    even = jnp.ones((4,))
    odd = jnp.ones((3,))
    assert float(reg.dispatch("toy", "fancy", even)[0]) == -999.0
    # guard miss: odd batch re-routes this call to xla_ref
    np.testing.assert_allclose(reg.dispatch("toy", "fancy", odd), odd + 1)
    assert reg.fallback_counts[("toy", "fancy")] == 1
    # auto selection also respects the guard at dispatch time
    np.testing.assert_allclose(reg.dispatch("toy", None, odd), odd + 1)


def test_real_guard_linear_attention_chunk_divisibility():
    from repro.kernels import linear_attention as la

    rs = np.random.RandomState(2)
    q = jnp.asarray(rs.randn(2, 20, 4), jnp.float32)    # T=20 % 16 != 0
    k = jnp.asarray(rs.randn(2, 20, 4), jnp.float32)
    v = jnp.asarray(rs.randn(2, 20, 4), jnp.float32)
    lw = jnp.full((2, 20, 4), -0.5, jnp.float32)
    before = dict(registry.default_registry.fallback_counts)
    out = la.linear_attention(q, k, v, lw, chunk=16, impl="pallas_interpret")
    ref = la.linear_attention(q, k, v, lw, chunk=4, impl="xla_ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)
    after = registry.default_registry.fallback_counts
    key = ("linear_attention", "pallas_interpret")
    assert after.get(key, 0) == before.get(key, 0) + 1


# -- spec-point integration -------------------------------------------------------

def _matmul_builder(spec):
    impl = impl_point(spec, "matmul", default="xla")

    def handler(x, y):
        return matmul.matmul(x, y, bm=16, bn=16, bk=16, impl=impl)

    return handler


def test_impl_point_roundtrip_through_handler_specialize():
    rt = IridescentRuntime(async_compile=False)
    h = rt.register("mm", _matmul_builder)

    space = h.spec_space()
    assert "matmul_impl" in space
    assert set(space["matmul_impl"].choices) == set(registry.choices("matmul"))

    x = jnp.asarray(np.random.RandomState(3).randn(32, 32), jnp.float32)
    y = jnp.asarray(np.random.RandomState(4).randn(32, 32), jnp.float32)
    ref = np.asarray(h(x, y))                           # generic (default)

    for name in registry.choices("matmul"):
        h.specialize({"matmul_impl": name}, wait=True)
        assert h.active_config() == {"matmul_impl": name}
        np.testing.assert_allclose(np.asarray(h(x, y)), ref,
                                   rtol=1e-4, atol=1e-4)

    h.despecialize()
    assert h.active_config() == {}


def test_explorer_selects_xla_ref_on_cpu():
    """The acceptance scenario: sweeping the impl point online on a CPU-only
    host must converge on xla_ref (the interpreter entry is orders of
    magnitude slower), purely from the measured throughput."""
    from repro.core import ChangeDetector

    rt = IridescentRuntime(async_compile=False)
    h = rt.register("mm_explore", _matmul_builder)

    # 128x128 over 16-tiles: the interpreter emulates a 512-step grid, a
    # ~50x measured gap vs xla_ref — far beyond scheduler noise.
    x = jnp.asarray(np.random.RandomState(5).randn(128, 128), jnp.float32)
    y = jnp.asarray(np.random.RandomState(6).randn(128, 128), jnp.float32)
    h(x, y)
    # warm up every candidate once so one-time process costs (tracing,
    # executable load) don't pollute the first measured dwell window
    for name in registry.choices("matmul"):
        h.specialize({"matmul_impl": name}, wait=True)
        jax.block_until_ready(h(x, y))
    h.despecialize()

    # loose change threshold: python-overhead jitter in the tiny exploit
    # windows must not re-trigger exploration mid-test
    ex = Explorer(h, ExhaustiveSweep.from_space(h.spec_space(),
                                                ["matmul_impl"]),
                  dwell=5, change_detector=ChangeDetector(threshold=5.0))
    for _ in range(10 * len(registry.choices("matmul")) + 10):
        jax.block_until_ready(h(x, y))
        ex.step()
    assert ex.phase is Phase.EXPLOIT
    assert h.active_config()["matmul_impl"] == FALLBACK_IMPL


def test_tpu_tuned_config_replays_on_cpu():
    """A config naming an impl that is unavailable on this host (e.g. tuned
    on a TPU pod, replayed on CPU CI) must specialize and degrade to
    xla_ref at dispatch — not be rejected by spec validation."""
    rt = IridescentRuntime(async_compile=False)
    h = rt.register("mm_replay", _matmul_builder)
    x = jnp.asarray(np.random.RandomState(7).randn(32, 32), jnp.float32)
    y = jnp.asarray(np.random.RandomState(8).randn(32, 32), jnp.float32)
    ref = np.asarray(h(x, y))

    h.specialize({"matmul_impl": "pallas_tpu"}, wait=True)   # unavailable
    np.testing.assert_allclose(np.asarray(h(x, y)), ref, rtol=1e-5,
                               atol=1e-5)
    h.specialize({"matmul_impl": "interpret"}, wait=True)    # legacy alias
    np.testing.assert_allclose(np.asarray(h(x, y)), ref, rtol=1e-4,
                               atol=1e-4)
    with pytest.raises(ValueError):
        h.specialize({"matmul_impl": "not_an_impl"}, wait=True)


def test_attention_guard_covers_block_divisibility():
    from repro.kernels import attention as attn

    rs = np.random.RandomState(9)
    q = jnp.asarray(rs.randn(1, 2, 192, 16), jnp.float32)   # 192 % 128 != 0
    k = jnp.asarray(rs.randn(1, 2, 192, 16), jnp.float32)
    v = jnp.asarray(rs.randn(1, 2, 192, 16), jnp.float32)
    before = registry.default_registry.fallback_counts.get(
        ("attention", "pallas_interpret"), 0)
    out = attn.attention(q, k, v, block_q=128, block_kv=128,
                         impl="pallas_interpret")
    ref = attn.attention(q, k, v, impl="xla_ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    after = registry.default_registry.fallback_counts[
        ("attention", "pallas_interpret")]
    assert after == before + 1


def test_require_grad_pins_concrete_grad_safe_impl():
    """Differentiated builders must never leave the impl on auto: dispatch
    cannot know a call sits under jax.grad, so impl_point(require_grad=True)
    returns a concrete grad-safe name even when the point is disabled or
    the default is a non-differentiable kernel."""
    from repro.core.specializer import SpecCtx

    for default in (None, "xla", "pallas_tpu", "pallas_interpret"):
        spec = SpecCtx({})                       # point disabled -> default
        value = impl_point(spec, "matmul", default=default,
                           require_grad=True)
        assert value is not None
        assert registry.get("matmul", value).supports_grad, (default, value)
    # grad actually flows through the pinned choice
    spec = SpecCtx({})
    impl = impl_point(spec, "rmsnorm", default="pallas_interpret",
                      require_grad=True)
    x = jnp.ones((4, 8), jnp.float32)
    w = jnp.ones((8,), jnp.float32)
    g = jax.grad(lambda a: rmsnorm.rmsnorm(a, w, impl=impl).sum())(x)
    assert bool(jnp.isfinite(g).all())


# -- compat layer -----------------------------------------------------------------

def test_compat_surface():
    # the shim must resolve on this host: shard_map callable, tree utils,
    # and the TPU compiler-params builder either None or constructible.
    assert callable(compat.shard_map)
    assert compat.tree_map(lambda a: a + 1, {"x": 1}) == {"x": 2}
    params = compat.tpu_compiler_params(
        dimension_semantics=("parallel",), not_a_real_field=1)
    if compat.has_pallas_tpu():
        assert params is not None
    assert compat.backend() == "cpu"


def test_no_direct_experimental_imports_outside_compat():
    """Repo-wide drift firewall: jax.experimental.shard_map and
    jax.experimental.pallas.* are imported only through repro.compat."""
    import pathlib
    import re

    src_root = pathlib.Path(registry.__file__).resolve().parents[2]
    offenders = []
    for path in src_root.rglob("*.py"):
        if path.name == "compat.py":
            continue
        text = path.read_text()
        if re.search(r"jax\.experimental\.shard_map|"
                     r"from jax\.experimental import shard_map|"
                     r"from jax\.experimental\.pallas import|"
                     r"from jax\.experimental import pallas", text):
            offenders.append(str(path))
    assert not offenders, offenders
