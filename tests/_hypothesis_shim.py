"""Minimal offline stand-in for ``hypothesis``.

The tier-1 suite must collect and pass on hosts with no network access, so
when the real ``hypothesis`` package is absent, ``conftest.py`` installs
this shim under the ``hypothesis`` / ``hypothesis.strategies`` module names.

Semantics: ``@given`` runs the wrapped test over a *fixed* set of examples
drawn deterministically (seeded per test name) from the strategy objects —
property tests degrade to parameterized example tests rather than being
skipped.  Only the strategy surface the repo's tests use is implemented:
``integers``, ``booleans``, ``sampled_from``, ``lists``, ``tuples``,
``dictionaries``; plus ``settings(max_examples=..., deadline=...)``.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
import zlib
from typing import Any, Callable, Sequence

DEFAULT_MAX_EXAMPLES = 10
_MAX_UNIQUE_RETRIES = 200


class Strategy:
    """Base: a strategy draws one value from a ``random.Random``."""

    def draw(self, rng: random.Random) -> Any:
        raise NotImplementedError


class _Integers(Strategy):
    def __init__(self, lo: int, hi: int):
        self.lo, self.hi = lo, hi

    def draw(self, rng):
        return rng.randint(self.lo, self.hi)


class _Booleans(Strategy):
    def draw(self, rng):
        return rng.random() < 0.5


class _SampledFrom(Strategy):
    def __init__(self, options: Sequence[Any]):
        self.options = list(options)

    def draw(self, rng):
        return rng.choice(self.options)


class _Tuples(Strategy):
    def __init__(self, *parts: Strategy):
        self.parts = parts

    def draw(self, rng):
        return tuple(p.draw(rng) for p in self.parts)


class _Lists(Strategy):
    def __init__(self, elem: Strategy, min_size: int = 0,
                 max_size: int | None = None, unique: bool = False):
        self.elem = elem
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 8
        self.unique = unique

    def draw(self, rng):
        size = rng.randint(self.min_size, self.max_size)
        out: list = []
        tries = 0
        while len(out) < size and tries < _MAX_UNIQUE_RETRIES:
            v = self.elem.draw(rng)
            tries += 1
            if self.unique and v in out:
                continue
            out.append(v)
        return out


class _Dictionaries(Strategy):
    def __init__(self, keys: Strategy, values: Strategy, min_size: int = 0,
                 max_size: int | None = None):
        self.keys = keys
        self.values = values
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 8

    def draw(self, rng):
        size = rng.randint(self.min_size, self.max_size)
        out: dict = {}
        tries = 0
        while len(out) < size and tries < _MAX_UNIQUE_RETRIES:
            tries += 1
            out[self.keys.draw(rng)] = self.values.draw(rng)
        return out


def integers(min_value: int = 0, max_value: int = 1 << 30) -> Strategy:
    return _Integers(min_value, max_value)


def booleans() -> Strategy:
    return _Booleans()


def sampled_from(options: Sequence[Any]) -> Strategy:
    return _SampledFrom(options)


def tuples(*parts: Strategy) -> Strategy:
    return _Tuples(*parts)


def lists(elem: Strategy, *, min_size: int = 0, max_size: int | None = None,
          unique: bool = False) -> Strategy:
    return _Lists(elem, min_size=min_size, max_size=max_size, unique=unique)


def dictionaries(keys: Strategy, values: Strategy, *, min_size: int = 0,
                 max_size: int | None = None) -> Strategy:
    return _Dictionaries(keys, values, min_size=min_size, max_size=max_size)


def given(*arg_strategies: Strategy, **kw_strategies: Strategy) -> Callable:
    """Run the test over a fixed, deterministically drawn example set."""

    def deco(test: Callable) -> Callable:
        @functools.wraps(test)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(test.__qualname__.encode())
            rng = random.Random(seed)
            for _ in range(n):
                drawn = tuple(s.draw(rng) for s in arg_strategies)
                drawn_kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                test(*args, *drawn, **kwargs, **drawn_kw)

        # mimic real hypothesis' attribute shape: plugins (e.g. anyio)
        # probe fn.hypothesis.inner_test to unwrap property tests.
        marker = types.SimpleNamespace(inner_test=test)
        wrapper.hypothesis = marker
        # hide the drawn parameters from pytest's fixture resolution: the
        # wrapper is invoked with no arguments, like real hypothesis tests.
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES,
             deadline: Any = None, **_ignored: Any) -> Callable:
    del deadline

    def deco(fn: Callable) -> Callable:
        # applies above @given: cap the wrapper's example count
        fn._shim_max_examples = min(max_examples, 25)
        return fn

    return deco


def assume(condition: Any) -> bool:
    """Real hypothesis prunes the example; the shim just skips via assert."""
    if not condition:
        raise AssertionError("shim assume() got a falsy condition; "
                             "restrict the strategy instead")
    return True


def install() -> None:
    """Register this shim as ``hypothesis`` (+ ``.strategies``) in
    ``sys.modules`` so existing ``from hypothesis import ...`` lines work."""
    import sys
    import types

    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "booleans", "sampled_from", "tuples", "lists",
                 "dictionaries"):
        setattr(st_mod, name, globals()[name])

    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = given
    hyp_mod.settings = settings
    hyp_mod.assume = assume
    hyp_mod.strategies = st_mod
    hyp_mod.__is_repro_shim__ = True

    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod
