"""Fast-path specialization (Morpheus analog): correctness property —
fastpath(x) == generic(x) for ALL x (hits and misses)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.fastpath import FastPathTable, build_table, make_fastpath
from repro.core.instrumentation import HostRecorder


def _generic(xb):
    xb = jnp.atleast_2d(xb)
    return (xb.astype(jnp.float32) ** 2).sum(-1, keepdims=True) + 1.0


@settings(max_examples=30, deadline=None)
@given(
    st.lists(st.tuples(st.integers(0, 50), st.integers(0, 50)),
             min_size=1, max_size=8, unique=True),
    st.lists(st.tuples(st.integers(0, 50), st.integers(0, 50)),
             min_size=1, max_size=16),
    st.booleans(),
)
def test_property_fastpath_equals_generic(table_keys, queries, skip):
    keys = np.asarray(table_keys, np.int32)
    vals = np.asarray(_generic(jnp.asarray(keys)))
    fp = make_fastpath(_generic, FastPathTable.from_arrays(keys, vals),
                       skip_generic_when_all_hit=skip)
    q = jnp.asarray(np.asarray(queries, np.int32))
    np.testing.assert_allclose(fp(q), _generic(q), rtol=1e-6)


def test_scalar_input_shape():
    keys = np.array([[1, 2]], np.int32)
    vals = np.asarray(_generic(jnp.asarray(keys)))
    fp = make_fastpath(_generic, FastPathTable.from_arrays(keys, vals))
    out = fp(jnp.array([1, 2], jnp.int32))
    assert out.shape == (1,)


def test_build_table_from_instrumentation():
    rec = HostRecorder("key", lambda a, k: int(a[0]), rate=1.0)
    for v in [5, 5, 5, 3, 3, 9]:
        rec.maybe_record((v,), {})
    observed = {"key": rec.summary()}

    def gen(k):
        return np.asarray(k, np.float64) * 2.0

    table = build_table(observed, "key", n=2, generic_fn=gen)
    assert table.n == 2
    top_keys = {int(np.asarray(k)[0]) for k in table.keys}
    assert top_keys == {5, 3}


def test_table_none_when_no_data():
    assert build_table({}, "key", 4, lambda k: k) is None
