"""Multi-device tests (8 fake CPU devices) in a subprocess, since the main
test process must keep the real single-device view.

Covers: small-mesh dry-run lower+compile for a reduced arch of each family
(the miniature of launch/dryrun.py), sharded train-step numerics vs
single-device, and the int8 compressed-psum collective.
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"   # never probe TPU/GPU runtimes here
import json
import jax, jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.dryrun import run_cell, build_lowerable
from repro.launch import dryrun as dr
from repro.optim import OptConfig
from repro.configs import Shape

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
out = {}

# 1) miniature dry-run: one reduced arch per family, train + decode
for arch in ["yi-6b", "deepseek-v2-236b", "rwkv6-1.6b", "hymba-1.5b"]:
    cfg = configs.get_reduced(arch)
    shape_t = Shape("t", "train", 64, 8)
    shape_d = Shape("d", "decode", 64, 8)
    for shape in (shape_t, shape_d):
        step, args, kw = build_lowerable(cfg, shape, mesh, {}, OptConfig(),
                                         scan_layers=True)
        compiled = jax.jit(step, **kw).lower(*args).compile()
        from repro import compat
        ca = compat.cost_analysis(compiled)
        out[f"{arch}:{shape.kind}"] = float(ca.get("flops", 0))

# 1b) shard_map expert-parallel MoE == dense oracle (ample capacity)
from repro.models.moe import init_moe, apply_moe, MoEOptions
from repro.models.config import ModelConfig
from repro.distributed.sharding import mesh_context, DEFAULT_RULES

cfg_m = ModelConfig(name="m", family="moe", n_layers=1, d_model=32,
                    n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                    n_experts=8, top_k=2, moe_d_ff=48, n_shared_experts=1)
pm = init_moe(jax.random.PRNGKey(0), cfg_m)
xm = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32), jnp.float32)


def run_moe(impl):
    def f(p_, x_):
        with mesh_context(mesh, DEFAULT_RULES):
            o, aux = apply_moe(p_, x_, cfg_m,
                               MoEOptions(impl=impl, capacity_factor=8.0))
            return o
    return jax.jit(f)(pm, xm)


o_dense = run_moe("dense")
o_shard = run_moe("shard")
out["shard_moe_err"] = float(jnp.abs(o_shard - o_dense).max())

# shard impl must be differentiable (training path)
def loss_fn(p_):
    with mesh_context(mesh, DEFAULT_RULES):
        o, aux = apply_moe(p_, xm, cfg_m,
                           MoEOptions(impl="shard", capacity_factor=8.0))
        return jnp.sum(o ** 2) + aux
g = jax.jit(jax.grad(loss_fn))(pm)
out["shard_moe_grad_finite"] = bool(
    all(bool(jnp.isfinite(x).all()) for x in jax.tree_util.tree_leaves(g)))

# 2) compressed psum: int8 all-gather appears in HLO, result ~= plain psum
from repro.distributed.compression import compressed_psum
x = jnp.asarray(np.random.RandomState(0).randn(64, 64).astype(np.float32))
compiled = jax.jit(lambda v: compressed_psum(v, "data", mesh)).lower(
    jax.ShapeDtypeStruct(x.shape, x.dtype)).compile()
hlo = compiled.as_text()
out["int8_allgather_in_hlo"] = ("s8" in hlo and "all-gather" in hlo)
got = jax.jit(lambda v: compressed_psum(v, "data", mesh))(x)
# replicated input: psum over axis of size 2 = 2*x, quantized
err = float(jnp.abs(got - 2 * x).max() / jnp.abs(x).max())
out["compressed_psum_rel_err"] = err

print("RESULT" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


def test_small_mesh_dryrun_compiles(results):
    for key in ["yi-6b:train", "yi-6b:decode", "deepseek-v2-236b:train",
                "rwkv6-1.6b:train", "hymba-1.5b:decode"]:
        assert results[key] > 0, key


def test_compressed_psum(results):
    assert results["int8_allgather_in_hlo"]
    assert results["compressed_psum_rel_err"] < 0.02   # int8 quant error


def test_shard_map_moe(results):
    assert results["shard_moe_err"] < 2e-5
    assert results["shard_moe_grad_finite"]
