"""Safe online exploration: canary dispatch slot, shadow evaluation,
SafetyController lifecycle (shadow -> canary -> promote -> rollback ->
quarantine), fleet quarantine propagation + plane gc, and v3 spec-state
crash consistency."""
import json
import math
import os

import jax.numpy as jnp
import pytest

from repro.core import (ChangeDetector, ContextualBandit, Controller,
                        CostAwareUCB, DEFAULT_CONTEXT, ExhaustiveSweep,
                        IridescentRuntime, Phase, Quarantine,
                        SafetyController, config_key, encode_context_key)
from repro.serve import ShadowEvaluator
from repro.serve.fleet import SpecPlane


def make_rt(**kw):
    return IridescentRuntime(async_compile=False, **kw)


def _mode_builder(spec):
    mode = spec.enum("mode", "a", ("a", "b", "bad"), guarded=False)

    def f(x):
        return x * (1.0 if mode == "a" else 2.0 if mode == "b" else 3.0)

    return f


def _mm_builder(spec):
    B = spec.enum("B", 8, (4, 8, 16))

    def matmul(L, R):
        return (L @ R) * 1.0

    return matmul


# --- runtime: canary dispatch slot ----------------------------------------------

def test_canary_slot_routes_fraction_and_promotes():
    rt = make_rt()
    h = rt.register("m", _mode_builder)
    h(jnp.ones(4))
    view = h.context(DEFAULT_CONTEXT)
    view.set_canary({"mode": "b"}, 0.25, wait=True)
    assert view.canary_config() == {"mode": "b"}
    for _ in range(8):
        h(jnp.ones(4))
    # period = round(1/0.25) = 4: tickets 0 and 4 of the 8 routed to it
    assert view.canary_calls() == 2
    assert view.active_config() == {}        # incumbent still owns the slot
    promoted = view.promote_canary(wait=True)
    assert promoted == {"mode": "b"}
    assert view.active_config() == {"mode": "b"}
    assert view.canary_config() is None
    rt.shutdown()


def test_clear_canary_and_revert_to():
    rt = make_rt()
    h = rt.register("m", _mode_builder)
    h(jnp.ones(4))
    view = h.context(DEFAULT_CONTEXT)
    view.set_canary({"mode": "b"}, 0.5, wait=True)
    view.clear_canary()
    assert view.canary_config() is None
    n0 = view.canary_calls()
    for _ in range(6):
        h(jnp.ones(4))
    assert view.canary_calls() == n0         # withdrawn: no more routing
    view.specialize({"mode": "bad"}, wait=True)
    view.set_canary({"mode": "b"}, 0.5, wait=True)
    view.revert_to({"mode": "a"}, wait=True)  # rollback empties the slot too
    assert view.active_config() == {"mode": "a"}
    assert view.canary_config() is None
    rt.shutdown()


def test_shadow_tap_sees_live_calls():
    rt = make_rt()
    h = rt.register("m", _mode_builder)
    seen = []
    h.set_shadow_tap(lambda key, args, kwargs: seen.append(key))
    h(jnp.ones(4))
    h(jnp.ones(4))
    assert len(seen) == 2
    h.clear_shadow_tap()
    h(jnp.ones(4))
    assert len(seen) == 2
    rt.shutdown()


# --- ShadowEvaluator ------------------------------------------------------------

def _iters_builder(spec):
    # mode "slow" does 200x the work of "fast": a timing gap no shared CI
    # host can invert, so the in_slo verdicts below are deterministic.
    iters = spec.enum("iters", 1, (1, 200), guarded=False)

    def f(x):
        y = x
        for _ in range(iters):
            y = y @ x
        return y

    return f


def test_shadow_evaluator_passes_faster_candidate():
    rt = make_rt()
    h = rt.register("m", _iters_builder)
    ev = ShadowEvaluator(h, sample_frac=1.0, k=3, tolerance=1.5)
    x = jnp.eye(32)
    h(x)
    view = h.context(DEFAULT_CONTEXT)
    view.specialize({"iters": 200}, wait=True)   # slow incumbent
    ev.begin(DEFAULT_CONTEXT, {"iters": 1}, view.active_config())
    view.build({"iters": 1}, wait=True)
    for _ in range(3):
        h(x)                                 # captured by the tap
    while ev.verdict(DEFAULT_CONTEXT) is None:
        assert ev.step(budget=4) > 0
    v = ev.verdict(DEFAULT_CONTEXT)
    assert v["measured"] and v["pairs"] >= 3 and v["in_slo"]
    assert v["candidate_s"] < v["incumbent_s"]
    # candidate was exercised off the hot path: live slot never changed
    assert view.active_config() == {"iters": 200}
    ev.close()
    rt.shutdown()


def test_shadow_evaluator_rejects_slow_candidate():
    rt = make_rt()
    h = rt.register("m", _iters_builder)
    ev = ShadowEvaluator(h, sample_frac=1.0, k=3, tolerance=1.5)
    x = jnp.eye(32)
    h(x)
    view = h.context(DEFAULT_CONTEXT)
    ev.begin(DEFAULT_CONTEXT, {"iters": 200}, view.active_config())
    view.build({"iters": 200}, wait=True)
    for _ in range(3):
        h(x)
    while ev.verdict(DEFAULT_CONTEXT) is None:
        assert ev.step(budget=4) > 0
    v = ev.verdict(DEFAULT_CONTEXT)
    assert v["measured"] and not v["in_slo"]
    assert v["candidate_s"] > v["incumbent_s"]
    ev.close()
    rt.shutdown()


def test_shadow_evaluator_samples_by_fraction_and_caps():
    rt = make_rt()
    h = rt.register("m", _mode_builder)
    ev = ShadowEvaluator(h, sample_frac=0.5, max_samples=3)
    for _ in range(10):
        h(jnp.ones(4))
    st = ev._st(DEFAULT_CONTEXT)
    assert len(st.samples) == 3              # every 2nd call, capped at 3
    assert st.tick == 10
    ev.close()
    rt.shutdown()


def test_shadow_evaluator_fails_safe_without_measurements():
    rt = make_rt()
    h = rt.register("m", _mode_builder)
    ev = ShadowEvaluator(h, sample_frac=1.0, k=2, max_attempts=2)
    h(jnp.ones(4))
    ev.begin(DEFAULT_CONTEXT, {"mode": "b"}, {})
    # candidate never built: step() can't run pairs, attempts stay 0 and
    # the verdict stays None (still waiting on the build)...
    assert ev.step(budget=4) == 0
    assert ev.verdict(DEFAULT_CONTEXT) is None
    # ...but once the attempt budget is burned (stale samples), the
    # verdict is a fail-safe rejection, never a silent admission.
    ev._st(DEFAULT_CONTEXT).attempts = 2
    v = ev.verdict(DEFAULT_CONTEXT)
    assert v is not None and not v["in_slo"] and not v["measured"]
    ev.close()
    rt.shutdown()


# --- SafetyController lifecycle -------------------------------------------------

class FakeShadow:
    """Scripted shadow evaluator: verdicts keyed by candidate config."""

    def __init__(self, verdicts):
        self.verdicts = {config_key(c): dict(v) for c, v in verdicts}
        self.begun = []
        self.current = None

    def begin(self, key, candidate, incumbent):
        self.begun.append((key, dict(candidate), dict(incumbent)))
        self.current = dict(candidate)

    def verdict(self, key):
        if self.current is None:
            return None
        return self.verdicts[config_key(self.current)]

    def clear(self, key):
        self.current = None


def _drive_safety(h, ctl, rates, iters, sampled):
    for _ in range(iters):
        h(jnp.ones(4))
        h(jnp.ones(4))
        ctl.step()
        cfg = h.active_config()
        sampled.add(cfg.get("mode", "a"))


def test_safety_full_lifecycle_promote_rollback_quarantine():
    rt = make_rt()
    h = rt.register("m", _mode_builder)
    h(jnp.ones(4))
    rates = {"a": 10.0, "b": 12.0, "bad": 100.0}
    shadow = FakeShadow([
        ({"mode": "b"}, {"metric": 5.0, "in_slo": True}),
        ({"mode": "bad"}, {"metric": 0.5, "in_slo": False}),
    ])
    ctl = SafetyController(
        h, ExhaustiveSweep([{"mode": "b"}, {"mode": "bad"}]),
        shadow=shadow, canary_frac=0.25, promote_after=2,
        metric=lambda view: rates[view.active_config().get("mode", "a")],
        dwell=2, wait_compiles=True, prefetch=0,
        change_detector=ChangeDetector(0.3, warmup=1))
    sampled = set()
    _drive_safety(h, ctl, rates, 30, sampled)
    # both candidates shadowed against the incumbent, off the live path
    assert [c for _, c, _ in shadow.begun] == [{"mode": "b"},
                                               {"mode": "bad"}]
    assert ctl.shadow_rejections == 1
    # the in-SLO winner canaried and promoted; the rejected one never ran
    assert ctl.promotions == 1
    assert h.active_config() == {"mode": "b"}
    assert "bad" not in sampled
    status = ctl.safety_status()
    enc = encode_context_key(DEFAULT_CONTEXT)
    assert status["contexts"][enc]["promoted"]
    assert status["contexts"][enc]["last_known_good"] == {}
    # post-promotion regression: the promoted config degrades
    rates["b"] = 3.0
    _drive_safety(h, ctl, rates, 30, sampled)
    assert ctl.rollbacks == 1
    assert h.active_config() == {}           # reverted to last-known-good
    assert ctl.quarantine.blocked(h.name, DEFAULT_CONTEXT, {"mode": "b"})
    assert "bad" not in sampled
    # quarantined configs stay dead: keep serving, b never comes back
    _drive_safety(h, ctl, rates, 20, sampled)
    assert h.active_config() == {}
    state = ctl.safety_state()
    assert state["quarantined"][enc] == [{"mode": "b"}]
    assert ctl.safety_status()["rollbacks"] == 1
    rt.shutdown()


def test_shadow_rejected_config_never_elected_even_if_board_best():
    """A shadow-failed candidate whose (shadow) metric tops the board must
    not be elected; the incumbent keeps serving."""
    rt = make_rt()
    h = rt.register("m", _mode_builder)
    h(jnp.ones(4))
    shadow = FakeShadow([
        ({"mode": "bad"}, {"metric": 99.0, "in_slo": False}),
    ])
    ctl = SafetyController(
        h, ExhaustiveSweep([{"mode": "bad"}]), shadow=shadow,
        metric=lambda view: 10.0, dwell=2, wait_compiles=True, prefetch=0,
        change_detector=ChangeDetector(float("inf")))
    sampled = set()
    _drive_safety(h, ctl, {}, 20, sampled)
    assert ctl.shadow_rejections == 1
    assert ctl.promotions == 0
    assert h.active_config() == {}
    assert sampled == {"a"}
    rt.shutdown()


def test_safety_without_shadow_explores_live_but_canary_gates_swap():
    """shadow=None: candidates explore on live traffic (pre-safety
    behavior) but a winner that is not already serving still goes through
    canary probation before it owns the slot."""
    rt = make_rt()
    h = rt.register("m", _mode_builder)
    h(jnp.ones(4))
    rates = {"a": 10.0, "b": 12.0, "bad": 1.0}
    ctl = SafetyController(
        h, ExhaustiveSweep([{"mode": "b"}, {"mode": "bad"}]), shadow=None,
        canary_frac=0.5, promote_after=2,
        metric=lambda view: rates[view.active_config().get("mode", "a")],
        dwell=2, wait_compiles=True, prefetch=0,
        change_detector=ChangeDetector(0.3, warmup=1))
    sampled = set()
    _drive_safety(h, ctl, rates, 30, sampled)
    # live exploration did serve the losing candidate (no shadow to hide it)
    assert "bad" in sampled
    # but the winner was not swapped in directly: it canaried first
    assert ctl.promotions == 1
    assert h.active_config() == {"mode": "b"}
    assert ctl.settled()
    rt.shutdown()


def test_warm_started_safety_controller_never_reexplores_quarantined():
    """Quarantine restored from spec state blocks both the warm-start
    config and any re-proposal of it."""
    rt = make_rt()
    h = rt.register("m", _mode_builder)
    h(jnp.ones(4))
    quarantine = Quarantine()
    quarantine.add("m", DEFAULT_CONTEXT, {"mode": "b"})
    ctl = SafetyController(
        h, ExhaustiveSweep([{"mode": "b"}]), shadow=None,
        quarantine=quarantine,
        initial_configs={DEFAULT_CONTEXT: {"mode": "b"}},
        metric=lambda view: 10.0, dwell=2, wait_compiles=True, prefetch=0,
        change_detector=ChangeDetector(float("inf")))
    sampled = set()
    _drive_safety(h, ctl, {}, 20, sampled)
    assert h.active_config() == {}           # never restored, never proposed
    assert "b" not in sampled
    rt.shutdown()


# --- satellite: CostAwareUCB as the budget-gated default policy -----------------

def test_budget_gate_selects_cost_aware_default_policy():
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    h(jnp.ones((4, 4)), jnp.eye(4))
    ctl = Controller(h, candidates=[{"B": 4}, {"B": 8}], budget=100.0,
                     dwell=2, wait_compiles=True, prefetch=0)
    ctl.step()
    assert isinstance(ctl._ctls[DEFAULT_CONTEXT].policy, CostAwareUCB)
    rt.shutdown()


def test_no_budget_keeps_plain_bandit_default_policy():
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    h(jnp.ones((4, 4)), jnp.eye(4))
    ctl = Controller(h, candidates=[{"B": 4}, {"B": 8}],
                     dwell=2, wait_compiles=True, prefetch=0)
    ctl.step()
    policy = ctl._ctls[DEFAULT_CONTEXT].policy
    assert isinstance(policy, ContextualBandit)
    assert not isinstance(policy, CostAwareUCB)
    rt.shutdown()


def test_cost_weight_zero_is_veto_only():
    """cost_weight=0 must neutralize the acquisition penalty (proposals in
    plain candidate order) while the hard budget veto still gates the
    over-budget candidate."""
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    h(jnp.ones((4, 4)), jnp.eye(4))
    costs = {4: 0.001, 8: 0.009, 16: 1.0}    # 16 is over the veto ceiling
    ctl = Controller(
        h, candidates=[{"B": 8}, {"B": 4}, {"B": 16}],
        budget=1.0, cost_weight=0.0, sec_per_call_prior=0.01, dwell=2,
        cost_fn=lambda cfg: costs[cfg["B"]],
        metric=lambda view: float(view.active_config().get("B", 0)),
        wait_compiles=True, prefetch=0,
        change_detector=ChangeDetector(float("inf")))
    for _ in range(40):
        h(jnp.ones((4, 4)), jnp.eye(4))
        h(jnp.ones((4, 4)), jnp.eye(4))
        ctl.step()
    explored = [cfg["B"] for ph, cfg, _ in ctl.history
                if ph is Phase.EXPLORE]
    assert 16 not in explored                # vetoed: est 1.0 > 1.0 * 0.02
    # cost_weight=0: no cheapest-first reordering — candidate order kept
    assert explored[:2] == [8, 4]
    assert ctl.settled() and ctl.best()[0] == {"B": 8}
    rt.shutdown()


# --- satellite: decayed prior on re-exploration ---------------------------------

def test_reexploration_keeps_decayed_prior_after_single_dwell_spike():
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    h(jnp.ones((4, 4)), jnp.eye(4))
    scores = {4: 1.0, 8: 3.0, 16: 2.0}
    spike = {"on": False}

    def metric(view):
        if spike["on"]:
            spike["on"] = False              # a single-dwell transient
            return 30.0
        return scores[view.active_config().get("B")]

    ctl = Controller(
        h, ContextualBandit([{"B": v} for v in (4, 8, 16)], rounds=3),
        metric=metric, dwell=2, wait_compiles=True, prefetch=0,
        change_detector=ChangeDetector(0.5, warmup=1))
    for _ in range(30):
        h(jnp.ones((4, 4)), jnp.eye(4))
        h(jnp.ones((4, 4)), jnp.eye(4))
        ctl.step()
    assert ctl.settled() and ctl.best()[0] == {"B": 8}
    before = {config_key(s["config"]): s
              for s in ctl._ctls[DEFAULT_CONTEXT].policy.arm_stats()}
    spike["on"] = True                       # fires the change detector once
    for _ in range(40):
        h(jnp.ones((4, 4)), jnp.eye(4))
        h(jnp.ones((4, 4)), jnp.eye(4))
        ctl.step()
    ctx = ctl.status()[DEFAULT_CONTEXT]
    assert ctx["explorations"] >= 1
    after = {config_key(s["config"]): s
             for s in ctl._ctls[DEFAULT_CONTEXT].policy.arm_stats()}
    for key, stats in after.items():
        # decayed prior, not a from-scratch reset: every previously pulled
        # arm keeps >= 1 pull so its learned mean survives the spike
        if before[key]["pulls"] > 0:
            assert stats["pulls"] >= 1
            assert not math.isclose(stats["mean"], 0.0)
    assert ctl.settled() and ctl.best()[0] == {"B": 8}
    rt.shutdown()


# --- fleet: quarantine propagation + plane gc -----------------------------------

def test_plane_propagates_quarantine_between_replicas(tmp_path):
    qa, qb = Quarantine(), Quarantine()
    pa = SpecPlane(str(tmp_path), "A", quarantine=qa)
    qa.add("h", 8, {"mode": "x"})
    pa.publish("h", 8, {"mode": "y"}, goodput=5.0)
    pb = SpecPlane(str(tmp_path), "B", quarantine=qb)
    pb.resolve()
    assert qb.blocked("h", 8, {"mode": "x"})
    assert not qb.blocked("h", 8, {"mode": "y"})


def test_plane_poll_never_seeds_quarantined_winner(tmp_path):
    pa = SpecPlane(str(tmp_path), "A")
    pa.publish("m", DEFAULT_CONTEXT, {"mode": "b"}, goodput=5.0)
    rt = make_rt()
    h = rt.register("m", _mode_builder)
    qb = Quarantine()
    qb.add("m", DEFAULT_CONTEXT, {"mode": "b"})
    pb = SpecPlane(str(tmp_path), "B", quarantine=qb)
    pb.poll(rt)
    assert h.seeded_config(DEFAULT_CONTEXT) is None
    rt.shutdown()


def test_plane_gc_reclaims_superseded_and_retired_records(tmp_path):
    t = {"now": 0.0}
    clock = lambda: t["now"]  # noqa: E731
    pa = SpecPlane(str(tmp_path), "A", clock=clock)
    pb = SpecPlane(str(tmp_path), "B", clock=clock)
    pa.publish("h", 8, {"x": 1}, goodput=1.0)
    pb.resolve()                             # B sees A's epoch
    t["now"] = 1.0
    pb.publish("h", 8, {"x": 2}, goodput=2.0)    # supersedes A's record
    pa.publish("h", 16, {"x": 3}, goodput=1.0)   # A-only context
    assert pb.gc(5.0) == 0                   # nothing old enough yet
    t["now"] = 20.0
    # B reclaims A's superseded h/8 record but never A's h/16 (another
    # replica's active context is not B's to retire)
    assert pb.gc(5.0, active={("h", encode_context_key(8))}) == 1
    winners = pb.resolve()
    assert winners[("h", encode_context_key(8))]["config"] == {"x": 2}
    assert ("h", encode_context_key(16)) in winners
    # A retires its own h/16 record once the context leaves its active set
    assert pa.gc(5.0, active=set()) == 1
    winners = pa.resolve()
    assert ("h", encode_context_key(16)) not in winners
    # the still-active winner survives gc regardless of age
    t["now"] = 100.0
    assert pb.gc(5.0, active={("h", encode_context_key(8))}) == 0
    assert pb.resolve()[("h", encode_context_key(8))]["config"] == {"x": 2}


# --- v3 spec-state crash consistency --------------------------------------------

def _spec_paths(tmp_path):
    return str(tmp_path / "spec_state.json")


def _save_v3(tmp_path, quarantined_active=True):
    """Write a v3 state via the real saver: active config {"mode": "b"}
    with b quarantined and LKG {"mode": "a"} when requested."""
    from repro.checkpoint import save_spec_state
    rt = make_rt()
    h = rt.register("m", _mode_builder)
    h(jnp.ones(4))
    h.specialize({"mode": "b"}, wait=True)
    enc = encode_context_key(DEFAULT_CONTEXT)
    safety = None
    if quarantined_active:
        safety = {"m": {"last_known_good": {enc: {"mode": "a"}},
                        "quarantined": {enc: [{"mode": "b"}]}}}
    path = _spec_paths(tmp_path)
    save_spec_state(path, rt, safety=safety)
    rt.shutdown()
    return path


def test_v3_roundtrip_restores_lkg_not_quarantined(tmp_path):
    from repro.checkpoint import load_safety_state, restore_spec_state
    path = _save_v3(tmp_path)
    enc = encode_context_key(DEFAULT_CONTEXT)
    safe = load_safety_state(path)
    assert safe["m"]["last_known_good"][enc] == {"mode": "a"}
    assert safe["m"]["quarantined"][enc] == [{"mode": "b"}]
    rt = make_rt()
    h = rt.register("m", _mode_builder)
    assert restore_spec_state(path, rt, wait=True)
    # the active config was quarantined: the LKG is restored instead
    assert h.active_config() == {"mode": "a"}
    rt.shutdown()


def test_v3_quarantined_without_lkg_stays_generic(tmp_path):
    from repro.checkpoint import restore_spec_state, save_spec_state
    rt = make_rt()
    h = rt.register("m", _mode_builder)
    h(jnp.ones(4))
    h.specialize({"mode": "b"}, wait=True)
    enc = encode_context_key(DEFAULT_CONTEXT)
    path = _spec_paths(tmp_path)
    save_spec_state(path, rt,
                    safety={"m": {"quarantined": {enc: [{"mode": "b"}]}}})
    rt.shutdown()
    rt = make_rt()
    h = rt.register("m", _mode_builder)
    assert restore_spec_state(path, rt, wait=True) is False
    assert h.active_config() == {}           # never the quarantined config
    rt.shutdown()


def test_v2_file_loads_under_v3_reader(tmp_path):
    from repro.checkpoint import load_safety_state, restore_spec_state
    enc = encode_context_key(DEFAULT_CONTEXT)
    path = _spec_paths(tmp_path)
    with open(path, "w") as f:
        json.dump({"version": 2, "handlers": {
            "m": {"contexts": {enc: {"mode": "b"}}}}}, f)
    assert load_safety_state(path) == {}
    rt = make_rt()
    h = rt.register("m", _mode_builder)
    assert restore_spec_state(path, rt, wait=True)
    assert h.active_config() == {"mode": "b"}
    rt.shutdown()


def test_truncated_v3_file_restores_to_generic(tmp_path):
    from repro.checkpoint import load_safety_state, restore_spec_state
    path = _save_v3(tmp_path)
    with open(path) as f:
        blob = f.read()
    with open(path, "w") as f:
        f.write(blob[:len(blob) // 2])       # torn write / partial flush
    assert load_safety_state(path) == {}
    rt = make_rt()
    h = rt.register("m", _mode_builder)
    assert restore_spec_state(path, rt, wait=True) is False
    assert h.active_config() == {}
    rt.shutdown()


def test_malformed_v3_safety_fields_are_dropped_not_fatal(tmp_path):
    from repro.checkpoint import load_safety_state, restore_spec_state
    enc = encode_context_key(DEFAULT_CONTEXT)
    path = _spec_paths(tmp_path)
    with open(path, "w") as f:
        json.dump({"version": 3, "handlers": {"m": {
            "contexts": {enc: {"mode": "b"}},
            "last_known_good": "not-a-dict",
            "quarantined": {enc: "not-a-list", "bogus": [17]},
        }}}, f)
    assert load_safety_state(path) == {}     # advisory metadata dropped
    rt = make_rt()
    h = rt.register("m", _mode_builder)
    assert restore_spec_state(path, rt, wait=True)
    assert h.active_config() == {"mode": "b"}
    rt.shutdown()


def test_future_spec_state_version_still_refused(tmp_path):
    from repro.checkpoint import restore_spec_state
    path = _spec_paths(tmp_path)
    with open(path, "w") as f:
        json.dump({"version": 4, "handlers": {
            "m": {"contexts": {encode_context_key(DEFAULT_CONTEXT):
                               {"mode": "b"}}}}}, f)
    rt = make_rt()
    h = rt.register("m", _mode_builder)
    assert restore_spec_state(path, rt, wait=True) is False
    assert h.active_config() == {}
    rt.shutdown()


def test_plane_record_quarantine_roundtrip(tmp_path):
    from repro.checkpoint import load_plane_record, save_plane_record
    path = os.path.join(str(tmp_path), "rec.json")
    save_plane_record(path, handler="h", context="8", config={"x": 1},
                      goodput=2.0, epoch=3, replica="A", t=0.0,
                      quarantined=[{"x": 9}])
    rec = load_plane_record(path)
    assert rec["quarantined"] == [{"x": 9}]
    save_plane_record(path, handler="h", context="8", config={"x": 1},
                      goodput=2.0, epoch=4, replica="A", t=0.0)
    assert load_plane_record(path)["quarantined"] == []
