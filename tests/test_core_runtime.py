"""Runtime: trampoline dispatch, guards + fallback, async compile,
instrumentation.  Core invariant (paper §4.4.3): for every input, the
handler's observable behaviour equals the generic function's."""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import DISABLED, IridescentRuntime, guards


def _mm_builder(spec):
    B = spec.enum("B", 8, (4, 8, 16))
    N = spec.generic("N", None, guard=guards.shape_equals(0, 0))

    def matmul(L, R):
        return (L @ R) * 1.0  # B/N only affect codegen, not semantics

    return matmul


def make_rt(**kw):
    return IridescentRuntime(async_compile=False, **kw)


def test_generic_available_immediately():
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    out = h(jnp.ones((4, 4)), jnp.eye(4))
    assert out.shape == (4, 4)
    assert h.active_config() == {}


def test_specialize_and_guard_fallback():
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    h(jnp.ones((8, 8)), jnp.eye(8))
    h.specialize({"B": 4, "N": 8}, wait=True)
    h(jnp.ones((8, 8)), jnp.eye(8))
    assert h.guard_misses == 0
    # guard miss -> generic fallback, still correct
    out = h(jnp.ones((4, 4)), jnp.eye(4))
    assert h.guard_misses == 1
    np.testing.assert_allclose(out, np.ones((4, 4)))


def test_variant_cache_reuse():
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    h(jnp.ones((4, 4)), jnp.eye(4))
    h.specialize({"B": 4}, wait=True)
    h.specialize({"B": 16}, wait=True)
    n = len(h.variants())
    h.specialize({"B": 4}, wait=True)   # cached
    assert len(h.variants()) == n


def test_async_compile_off_critical_path():
    rt = IridescentRuntime(async_compile=True)
    try:
        h = rt.register("m", _mm_builder)
        h(jnp.ones((4, 4)), jnp.eye(4))
        h.specialize({"B": 16}, wait=False)
        # trampoline keeps serving (old variant) while compiling
        out = h(jnp.ones((4, 4)), jnp.eye(4))
        assert out.shape == (4, 4)
        deadline = time.time() + 20
        while h.active_config().get("B") != 16 and time.time() < deadline:
            time.sleep(0.05)
            h(jnp.ones((4, 4)), jnp.eye(4))
        assert h.active_config().get("B") == 16
    finally:
        rt.shutdown()


def test_compile_times_recorded():
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    h(jnp.ones((4, 4)), jnp.eye(4))
    h.specialize({"B": 4}, wait=True)
    stats = h.stats()
    assert stats["variants"] >= 2
    assert any(v is not None for v in stats["compile_times_s"].values())


def test_host_instrumentation_collects_topk():
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    h(jnp.ones((4, 4)), jnp.eye(4))
    h.enable_instrumentation(
        rate=1.0, collectors={"N": lambda a, k: a[0].shape[0]})
    for n in (4, 4, 4, 8):
        h(jnp.ones((n, n)), jnp.eye(n))
    obs = h.spec_space().observed
    assert obs["N"]["top"][0][0] == 4
    h.disable_instrumentation()


def test_custom_spec_generator():
    rt = make_rt()
    rt.add_custom_spec("scaler", lambda payload: float(payload) * 2)

    def b(spec):
        s = spec.custom("s", "scaler")
        return lambda x: x * (s if s is not None else 1.0)

    h = rt.register("h", b)
    assert float(h(jnp.float32(3))) == 3.0
    h.specialize({"s": 2}, wait=True)
    assert float(h(jnp.float32(3))) == 12.0


def test_runtime_routes_config_subsets():
    rt = make_rt()
    rt.register("m", _mm_builder)

    def b2(spec):
        k = spec.enum("K", 1, (1, 2))
        return lambda x: x * k

    rt.register("other", b2)
    rt.specialize({"B": 4, "K": 2}, wait=True)
    assert rt.handler("m").active_config().get("B") == 4
    assert rt.handler("other").active_config().get("K") == 2


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.sampled_from([4, 8, 16]),
       st.booleans())
def test_property_specialized_equals_generic(n, b_choice, specialize):
    """For ANY input and ANY configuration, handler output == generic
    output (the paper's correctness guarantee)."""
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    x = jnp.arange(n * n, dtype=jnp.float32).reshape(n, n)
    generic = np.asarray(x @ jnp.eye(n))
    if specialize:
        h.specialize({"B": b_choice, "N": 8}, wait=True)  # guard vs n!=8
    out = h(x, jnp.eye(n))
    np.testing.assert_allclose(out, generic, rtol=1e-6)
