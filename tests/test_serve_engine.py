"""ServeEngine integration: continuous batching over the contextual
specialization runtime — retire-on-completion, idle ticks, backpressure,
mid-stream bucket re-tunes, tuner settling, and the drain-and-restart
zero-recompile acceptance path."""
import os

import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import restore_spec_state
from repro.core import (ChangeDetector, Controller, ExhaustiveSweep,
                        IridescentRuntime)
from repro.serve import (AdmissionQueue, BucketTuner, ContinuousBatcher,
                         FCFS, OpenLoopSource, Request, ServeEngine,
                         ServeMetrics, ShortestJobFirst, bucket_plan_builder)

D = 8


def _toy_builder(spec):
    scale = spec.enum("scale", 1, (1, 2), guarded=False)

    def f(x, w):
        return (x @ w) * float(scale)

    return f


def _batch_ctx(args, kwargs):
    return int(args[0].shape[0])


class ToyExecutor:
    """Counts handler calls; one matmul per step, rows = padded bucket."""

    def __init__(self, handler):
        self.handler = handler
        self.w = jnp.eye(D, dtype=jnp.float32)
        self.calls = 0
        self.sizes = []
        self.retired = []

    def execute(self, batch):
        self.calls += 1
        self.sizes.append(batch.size)
        x = jnp.ones((batch.size, D), jnp.float32)
        jax.block_until_ready(self.handler(x, self.w))

    def retire(self, req):
        self.retired.append(req.rid)


def make_engine(max_batch=4, scheme=None, queue=None, controller=None,
                tuner=None, rt=None, metrics=None, slo_s=None,
                scheduler=None):
    rt = rt or IridescentRuntime(async_compile=False)
    handler = rt.register("toy", _toy_builder, context_fn=_batch_ctx)
    executor = ToyExecutor(handler)
    batcher = ContinuousBatcher(max_batch, scheme=scheme)
    engine = ServeEngine(handler, controller, batcher,
                         scheduler or FCFS(), executor=executor,
                         queue=queue if queue is not None
                         else AdmissionQueue(),
                         tuner=tuner, metrics=metrics, slo_s=slo_s)
    return rt, handler, engine, executor


def test_engine_serves_and_stamps_lifecycle():
    rt, handler, engine, ex = make_engine()
    reqs = [Request(max_new_tokens=3) for _ in range(2)]
    for r in reqs:
        assert engine.submit(r)
    engine.run()
    for r in reqs:
        assert r.done and not r.shed
        assert r.arrival_t <= r.service_t <= r.first_token_t <= r.finish_t
        assert r.generated == 3
    s = engine.stats()
    assert s["serve"]["completed"] == 2
    assert s["serve"]["completed_tokens"] == 6
    assert s["in_flight"] == 0
    assert sorted(ex.retired) == sorted(r.rid for r in reqs)
    rt.shutdown()


def test_empty_queue_idle_tick_makes_no_handler_call():
    rt, handler, engine, ex = make_engine()
    assert engine.step() == 0
    assert engine.step() == 0
    assert engine.idle_ticks == 2
    assert engine.steps == 0
    assert ex.calls == 0                      # no handler work on idle
    assert handler.tput.total() == 0
    rt.shutdown()


def test_request_retires_mid_batch_while_others_continue():
    rt, handler, engine, ex = make_engine(scheme="single")
    short = Request(max_new_tokens=2)
    long_ = Request(max_new_tokens=5)
    engine.submit(short), engine.submit(long_)
    engine.step()
    engine.step()                             # short's budget is spent here
    assert short.done and short.finish_t is not None
    assert engine.active == [long_]           # long keeps decoding
    assert ex.retired == [short.rid]
    engine.run()
    assert long_.done and long_.generated == 5
    assert engine.stats()["serve"]["completed"] == 2
    rt.shutdown()


def test_backpressure_rejection_at_capacity_no_shed_errors():
    rt, handler, engine, ex = make_engine(
        max_batch=2, scheme="single", queue=AdmissionQueue(depth=2))
    accepted = [r for r in (Request(max_new_tokens=2) for _ in range(6))
                if engine.submit(r)]
    stats = engine.queue.stats()
    assert len(accepted) == 2 and stats["rejected"] == 4
    engine.run()
    s = engine.stats()
    assert s["serve"]["completed"] == 2       # rejected ones never served
    assert s["queue"]["shed_errors"] == 0
    rt.shutdown()


def test_bucket_retune_mid_stream_keeps_in_flight_requests():
    rt, handler, engine, ex = make_engine(max_batch=4, scheme="pow2")
    reqs = [Request(max_new_tokens=6) for _ in range(3)]
    for r in reqs:
        engine.submit(r)
    engine.step()                             # 3 rows -> bucket 4
    assert ex.sizes[-1] == 4
    engine.batcher.set_scheme("single")       # re-tune between steps
    engine.run()
    assert ex.sizes[-1] == 4                  # cap is 4 either way
    for r in reqs:                            # nobody was dropped
        assert r.done and not r.shed and r.generated == 6
    assert engine.stats()["serve"]["completed"] == 3
    rt.shutdown()


def test_per_bucket_contexts_materialize():
    rt, handler, engine, ex = make_engine(max_batch=4, scheme="pow2")
    engine.submit(Request(max_new_tokens=2))
    engine.run()                              # 1 row -> bucket 1
    for r in (Request(max_new_tokens=2) for _ in range(4)):
        engine.submit(r)
    engine.run()                              # 4 rows -> bucket 4
    assert {1, 4} <= set(handler.contexts())
    rt.shutdown()


def test_drain_timeout_sheds_remainder():
    rt, handler, engine, ex = make_engine(scheme="single")
    stuck = Request(max_new_tokens=10**6)
    engine.submit(stuck)
    engine.step()
    assert not engine.drain(timeout_s=0.0)    # immediate timeout
    assert stuck.shed
    assert engine.active == []
    assert engine.stats()["serve"]["shed"] == 1
    assert not engine.submit(Request())       # admission closed
    rt.shutdown()


def test_tuner_settles_on_a_known_scheme():
    rt = IridescentRuntime(async_compile=False)
    handler = rt.register("toy", _toy_builder, context_fn=_batch_ctx)
    executor = ToyExecutor(handler)
    batcher = ContinuousBatcher(4)
    metrics = ServeMetrics(slo_s=60.0)
    tuner = BucketTuner(
        batcher, rt, metric=metrics.interval_goodput, dwell=3,
        wait_compiles=True,
        change_detector=lambda: ChangeDetector(float("inf")))
    engine = ServeEngine(handler, None, batcher, FCFS(), executor=executor,
                         queue=AdmissionQueue(), tuner=tuner,
                         metrics=metrics, slo_s=60.0)
    for _ in range(40):
        engine.submit(Request(max_new_tokens=2))
        engine.step()
    engine.drain(timeout_s=30.0)
    assert tuner.settled()
    assert tuner.active_scheme() in batcher.schemes
    assert tuner.best_scheme() in batcher.schemes
    status = tuner.status()
    assert status["boundaries"][status["active"]][-1] == 4
    rt.shutdown()


def _restart_stack(tmp_path, restore=False):
    """One serve 'process': runtime + handlers + engine wired to a
    persistent cache under tmp_path."""
    cache_dir = str(tmp_path / "state")
    rt = IridescentRuntime(async_compile=False,
                           variant_cache=os.path.join(cache_dir, "variants"))
    handler = rt.register("toy", _toy_builder, context_fn=_batch_ctx)
    batcher = ContinuousBatcher(4, scheme="pow2")
    plan_handler = rt.register(
        "bucket_plan",
        bucket_plan_builder(list(batcher.schemes), batcher.default_scheme))
    initial_scheme = None
    restored = False
    if restore:
        restored = restore_spec_state(
            os.path.join(cache_dir, "spec_state.json"), rt, wait=True)
        from repro.serve.batcher import BUCKET_POINT
        initial_scheme = plan_handler.active_config().get(BUCKET_POINT)
    controller = Controller(
        handler, lambda: ExhaustiveSweep([{"scale": 2}, {"scale": 1}]),
        dwell=3, wait_compiles=True, prefetch=0,
        change_detector=lambda: ChangeDetector(float("inf")))
    metrics = ServeMetrics(slo_s=60.0)
    tuner = BucketTuner(
        batcher, metric=metrics.interval_goodput, dwell=3,
        plan_handler=plan_handler, initial_scheme=initial_scheme,
        wait_compiles=True,
        change_detector=lambda: ChangeDetector(float("inf")))
    executor = ToyExecutor(handler)
    engine = ServeEngine(handler, controller, batcher, FCFS(),
                         executor=executor, queue=AdmissionQueue(),
                         tuner=tuner, metrics=metrics, slo_s=60.0)
    return cache_dir, rt, handler, plan_handler, controller, tuner, engine


def _serve_batch4_workload(engine, rounds=30):
    """Keep exactly 4 requests in flight so one context (bucket 4) absorbs
    the whole search deterministically."""
    for _ in range(rounds):
        while len(engine.active) + len(engine.queue) < 4:
            engine.submit(Request(max_new_tokens=2))
        engine.step()


def test_drain_and_restart_resumes_tuned_configs_with_zero_recompiles(
        tmp_path):
    """ISSUE acceptance: drain-and-restart resumes every context's tuned
    config (model handler per-bucket configs AND the tuned bucket scheme)
    with zero XLA recompiles."""
    (cache_dir, rt, handler, plan_handler,
     controller, tuner, engine) = _restart_stack(tmp_path)
    _serve_batch4_workload(engine, rounds=40)
    assert controller.settled() and tuner.settled()
    tuned_cfg = handler.active_config(context=4)
    tuned_scheme = tuner.active_scheme()
    assert tuned_cfg                               # the sweep picked one
    cold_compiles = rt.compile_stats()["xla_compiles"]
    assert cold_compiles > 0
    engine.shutdown(state_dir=cache_dir)           # drains + saves + stops
    assert os.path.exists(os.path.join(cache_dir, "spec_state.json"))

    # -- restart -------------------------------------------------------------
    (cache_dir, rt2, handler2, plan2,
     controller2, tuner2, engine2) = _restart_stack(tmp_path, restore=True)
    assert tuner2.active_scheme() == tuned_scheme  # scheme came back
    _serve_batch4_workload(engine2, rounds=10)
    engine2.drain(timeout_s=30.0)
    warm = rt2.compile_stats()
    assert handler2.active_config(context=4) == tuned_cfg
    assert warm["xla_compiles"] == 0, \
        f"warm restart recompiled: {warm}"
    assert warm["cache_hits"] > 0
    # warm start goes straight to EXPLOIT: no re-exploration happened
    assert controller2.settled(context=4)
    rt2.shutdown()


def test_drain_timeout_retires_in_flight_and_counts_shed_once():
    """Timeout shedding frees executor slots (retire hook) and counts each
    stranded request exactly once across queue + serve stats."""
    rt, handler, engine, ex = make_engine(max_batch=2, scheme="single")
    running = [Request(max_new_tokens=10**6) for _ in range(2)]
    waiting = Request(max_new_tokens=10**6)
    for r in running + [waiting]:
        engine.submit(r)
    engine.step()                             # two in flight, one waiting
    assert not engine.drain(timeout_s=0.0)
    assert sorted(ex.retired) == sorted(r.rid for r in running)
    s = engine.stats()
    assert s["serve"]["shed"] == 2            # in-flight sheds only
    assert s["queue"]["shed"] == 1            # the flushed waiter
    assert all(r.shed for r in running + [waiting])
    rt.shutdown()
