"""Per-arch smoke tests: reduced same-family config, one forward + one train
step on CPU, asserting output shapes + no NaNs; plus decode parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.specializer import specialize_builder
from repro.models import (KernelOptions, MoEOptions, RunOptions)
from repro.models import transformer as model
from repro.optim import OptConfig, init_opt_state
from repro.training import make_train_builder

OPTS = RunOptions(kernels=KernelOptions(impl="xla", chunk_len=8),
                  moe=MoEOptions(capacity_factor=4.0),
                  decode_cache_dtype="float32")


@pytest.fixture(scope="module", params=list(configs.ARCH_IDS))
def arch(request):
    return request.param


def _toks(cfg, b, s):
    return jax.random.randint(jax.random.PRNGKey(7), (b, s), 0,
                              cfg.vocab_size)


def test_forward_shapes_no_nans(arch):
    cfg = configs.get_reduced(arch).replace(compute_dtype="float32")
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    if cfg.frontend is not None:
        emb = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
        logits, aux = model.apply(params, cfg, OPTS, embeds=emb)
    else:
        logits, aux = model.apply(params, cfg, OPTS, tokens=_toks(cfg, B, S))
    assert logits.shape == (B, S, cfg.padded_vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


def test_train_step_runs_and_reduces_loss(arch):
    cfg = configs.get_reduced(arch).replace(compute_dtype="float32")
    opt_cfg = OptConfig(lr=1e-2, warmup_steps=1, total_steps=100)
    builder = make_train_builder(cfg, opt_cfg, kernel_impl="xla")
    step = jax.jit(specialize_builder(
        builder, {"capacity_factor": 2.0} if cfg.is_moe else {}).fn)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    state = {"params": params, "opt": init_opt_state(params, opt_cfg)}
    B, S = 4, 16
    toks = _toks(cfg, B, S + 1)
    batch = {"labels": toks[:, 1:]}
    if cfg.frontend is not None:
        batch["embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, S, cfg.d_model)) * 0.1
    else:
        batch["tokens"] = toks[:, :-1]
    losses = []
    for _ in range(5):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses)), losses
    assert losses[-1] < losses[0], losses       # memorizes a fixed batch


def test_decode_matches_forward(arch):
    cfg = configs.get_reduced(arch).replace(compute_dtype="float32")
    if cfg.frontend is not None:
        pytest.skip("decode parity via tokens only")
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    toks = _toks(cfg, B, S)
    logits, _ = model.apply(params, cfg, OPTS, tokens=toks)
    cache = model.init_cache(cfg, B, max_len=S, opts=OPTS)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t],
                                      jnp.int32(t), cfg, OPTS)
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    ref = logits.astype(jnp.float32)[:, :, : cfg.vocab_size]
    np.testing.assert_allclose(dec, ref, rtol=2e-3, atol=2e-3)


def test_param_count_formula(arch):
    """Analytic 6ND param count matches the actual pytree size."""
    cfg = configs.get_reduced(arch)
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
    claimed = cfg.param_count()
    # padded vocab + small dims make the analytic formula approximate at
    # reduced scale; require agreement within 20%.
    assert abs(actual - claimed) / max(actual, 1) < 0.2, (actual, claimed)
