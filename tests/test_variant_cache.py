"""Persistent variant cache: warm-restart round trip with zero recompiles,
corrupted-entry fallback, and the lock-free trampoline fast path (dispatch
overhead regression + atomic guard-miss counters)."""
import os
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import IridescentRuntime, VariantCache, guards


def _mm_builder(spec):
    B = spec.enum("B", 8, (4, 8, 16))

    def matmul(L, R):
        return (L @ R) * 1.0

    return matmul


def _run_once(cache_dir, specialize_cfg):
    rt = IridescentRuntime(async_compile=False, variant_cache=cache_dir)
    h = rt.register("m", _mm_builder)
    out_generic = h(jnp.ones((8, 8)), jnp.eye(8))
    h.specialize(specialize_cfg, wait=True)
    out_spec = h(jnp.ones((8, 8)), jnp.eye(8))
    stats = rt.compile_stats()
    from_cache = [v.from_cache for v in h.variants()]
    rt.shutdown()
    return np.asarray(out_generic), np.asarray(out_spec), stats, from_cache


def test_warm_restart_zero_recompiles(tmp_path):
    """Acceptance: a second run with a populated cache directory performs 0
    XLA recompiles for previously seen configs."""
    cache_dir = str(tmp_path / "variants")
    g1, s1, cold, _ = _run_once(cache_dir, {"B": 4})
    assert cold["xla_compiles"] >= 2            # generic + specialized
    assert cold["cache"]["stores"] >= 2
    g2, s2, warm, from_cache = _run_once(cache_dir, {"B": 4})
    assert warm["xla_compiles"] == 0            # zero recompiles on warm start
    assert warm["cache_hits"] >= 2
    assert all(from_cache)
    np.testing.assert_allclose(g1, g2)
    np.testing.assert_allclose(s1, s2)


def test_unseen_config_still_compiles_on_warm_start(tmp_path):
    cache_dir = str(tmp_path / "variants")
    _run_once(cache_dir, {"B": 4})
    _, _, stats, _ = _run_once(cache_dir, {"B": 16})   # new config
    assert stats["cache_hits"] >= 1             # generic came from cache
    assert stats["xla_compiles"] == 1           # only the unseen config


def test_corrupted_entry_falls_back_to_compile(tmp_path):
    cache_dir = str(tmp_path / "variants")
    _run_once(cache_dir, {"B": 4})
    cache = VariantCache(cache_dir)
    entries = cache.entries()
    assert entries
    for key in entries:                          # corrupt every entry
        with open(cache._path(key), "wb") as f:
            f.write(b"not a pickle at all")
    g, s, stats, _ = _run_once(cache_dir, {"B": 4})
    assert stats["xla_compiles"] >= 2            # recompiled from scratch
    assert stats["cache"]["errors"] >= 1
    np.testing.assert_allclose(s, np.ones((8, 8)))
    # bad entries were replaced by fresh ones: a third run hits again
    _, _, stats3, _ = _run_once(cache_dir, {"B": 4})
    assert stats3["xla_compiles"] == 0


def test_cache_key_distinguishes_arg_shapes(tmp_path):
    cache_dir = str(tmp_path / "variants")
    rt = IridescentRuntime(async_compile=False, variant_cache=cache_dir)
    h = rt.register("m", _mm_builder)
    h(jnp.ones((4, 4)), jnp.eye(4))
    rt.shutdown()
    # same handler/config, different shapes -> different entry, no bogus hit
    rt2 = IridescentRuntime(async_compile=False, variant_cache=cache_dir)
    h2 = rt2.register("m", _mm_builder)
    out = h2(jnp.ones((8, 8)), jnp.eye(8))
    assert out.shape == (8, 8)
    assert rt2.compile_stats()["cache_hits"] == 0
    rt2.shutdown()


# --- LRU eviction ----------------------------------------------------------------

def _fill_entry(cache, key, nbytes):
    """Write a raw entry of a known size (content irrelevant for eviction)."""
    with open(cache._path(key), "wb") as f:
        f.write(b"x" * nbytes)


def test_lru_eviction_by_last_used(tmp_path):
    """With max_bytes set, an insert evicts least-recently-used entries (by
    mtime) until the cache fits; the newest entry always survives."""
    cache = VariantCache(str(tmp_path), max_bytes=250)
    for i, key in enumerate(("aa", "bb", "cc")):
        _fill_entry(cache, key, 100)
        os.utime(cache._path(key), (i, i))       # distinct, ordered mtimes
    assert sorted(cache.entries()) == ["aa", "bb", "cc"]
    # touch 'aa' (most recently used now), then store a new entry: the cap
    # (250) forces evictions, oldest-mtime first -> 'bb' and 'cc' go
    os.utime(cache._path("aa"), None)
    # store() needs a serializable executable; drive the eviction path
    # directly the way store() does after a successful write
    _fill_entry(cache, "dd", 100)
    with cache._lock:
        cache._evict_lru_locked(keep=cache._path("dd"))
    assert sorted(cache.entries()) == ["aa", "dd"]
    assert cache.stats.evictions.value() == 2


def test_lru_keeps_oversized_just_written_entry(tmp_path):
    cache = VariantCache(str(tmp_path), max_bytes=50)
    _fill_entry(cache, "big", 100)
    with cache._lock:
        cache._evict_lru_locked(keep=cache._path("big"))
    assert cache.entries() == ["big"]             # never evict what we just stored


def test_lru_eviction_end_to_end(tmp_path):
    """Real store() path: a byte cap small enough for ~one AOT executable
    keeps the cache at its cap and bumps the eviction counter."""
    cache_dir = str(tmp_path / "variants")
    rt = IridescentRuntime(async_compile=False,
                           variant_cache=VariantCache(cache_dir, max_bytes=1))
    h = rt.register("m", _mm_builder)
    h(jnp.ones((4, 4)), jnp.eye(4))
    h.specialize({"B": 4}, wait=True)
    h.specialize({"B": 16}, wait=True)
    cache = rt.variant_cache
    if cache.stats.stores.value() >= 2:           # serialization available
        assert len(cache.entries()) <= 1          # cap enforced on insert
        assert cache.stats.evictions.value() >= 1
    rt.shutdown()


def test_unbounded_cache_never_evicts(tmp_path):
    cache_dir = str(tmp_path / "variants")
    _run_once(cache_dir, {"B": 4})
    cache = VariantCache(cache_dir)               # max_bytes=None
    assert cache.stats.evictions.value() == 0
    assert len(cache.entries()) >= 2


# --- trampoline fast path -------------------------------------------------------

class _CountingLock:
    """Lock wrapper that counts acquisitions (dispatch must not take any)."""

    def __init__(self, inner):
        self._inner = inner
        self.acquisitions = 0

    def acquire(self, *a, **k):
        self.acquisitions += 1
        return self._inner.acquire(*a, **k)

    def release(self):
        return self._inner.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False


def test_dispatch_fast_path_is_lock_free():
    """Regression: after warmup, a guardless dispatch takes no handler lock,
    runs no guard checks, and skips arg-spec capture (flag already down)."""
    rt = IridescentRuntime(async_compile=False)
    h = rt.register("m", _mm_builder)
    x, e = jnp.ones((4, 4)), jnp.eye(4)
    h(x, e)                                     # warmup: captures arg specs
    h.specialize({"B": 4}, wait=True)           # guardless specialized variant
    h(x, e)
    assert not h._need_arg_specs
    snap = h._snapshot
    assert snap.guard_fn is None                # guard check compiled away
    assert snap.fast is not None                # fast path engaged
    counting = _CountingLock(h._lock)
    h._lock = counting
    before = h.tput.count()
    for _ in range(100):
        h(x, e)
    assert counting.acquisitions == 0           # zero locking per call
    assert h.tput.count() - before == 100       # lock-free counting still exact
    rt.shutdown()


def test_guarded_variant_takes_slow_path_and_stays_correct():
    def b(spec):
        N = spec.generic("N", None, guard=guards.shape_equals(0, 0))
        return lambda L, R: (L @ R) * 1.0

    rt = IridescentRuntime(async_compile=False)
    h = rt.register("m", b)
    h(jnp.ones((8, 8)), jnp.eye(8))
    h.specialize({"N": 8}, wait=True)
    assert h._snapshot.fast is None             # guard forces the slow path
    out = h(jnp.ones((4, 4)), jnp.eye(4))       # guard miss -> generic
    np.testing.assert_allclose(out, np.ones((4, 4)))
    assert h.guard_misses == 1
    rt.shutdown()


def test_guard_miss_counters_are_atomic_under_threads():
    def b(spec):
        N = spec.generic("N", None, guard=guards.shape_equals(0, 0))
        return lambda L, R: (L @ R) * 1.0

    rt = IridescentRuntime(async_compile=False)
    h = rt.register("m", b)
    h(jnp.ones((8, 8)), jnp.eye(8))
    h.specialize({"N": 8}, wait=True)
    miss_l, miss_r = jnp.ones((4, 4)), jnp.eye(4)
    h(miss_l, miss_r)                           # compile the miss shape once
    base = h.guard_misses
    n_threads, n_calls = 8, 200

    def hammer():
        for _ in range(n_calls):
            h(miss_l, miss_r)

    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert h.guard_misses - base == n_threads * n_calls   # no lost updates
    rt.shutdown()


def test_aot_failure_is_transient_not_permanent(caplog):
    """A transient AOT error falls back to jit for that call, warns once,
    and does NOT permanently demote the variant."""
    rt = IridescentRuntime(async_compile=False)
    h = rt.register("m", _mm_builder)
    x, e = jnp.ones((4, 4)), jnp.eye(4)
    h(x, e)
    v = h._snapshot.variant
    assert v.compiled is not None
    real = v.compiled
    calls = {"n": 0}

    class Flaky:
        def __call__(self, *args):
            calls["n"] += 1
            if calls["n"] == 1:
                raise RuntimeError("transient placement error")
            return real(*args)

    v.compiled = Flaky()
    out = h(x, e)                               # transient failure -> jit
    np.testing.assert_allclose(out, np.ones((4, 4)))
    assert v.compiled is not None               # NOT demoted
    out = h(x, e)                               # AOT path recovered
    assert calls["n"] >= 2
    assert v._aot_failures == 0                 # success reset the streak
    rt.shutdown()


def test_aot_demotes_after_consecutive_failures():
    rt = IridescentRuntime(async_compile=False)
    h = rt.register("m", _mm_builder)
    x, e = jnp.ones((4, 4)), jnp.eye(4)
    h(x, e)
    v = h._snapshot.variant

    class AlwaysBroken:
        def __call__(self, *args):
            raise ValueError("layout mismatch")

    v.compiled = AlwaysBroken()
    for _ in range(5):
        out = h(x, e)                           # every call stays correct
        np.testing.assert_allclose(out, np.ones((4, 4)))
    assert v.compiled is None                   # demoted after the streak
    rt.shutdown()


# -- portable (replica-fleet) cache keys ---------------------------------------

def _fake_devices(n, kind="FakeCPU"):
    class _Dev:
        device_kind = kind

    return [_Dev() for _ in range(n)]


def test_default_cache_key_stays_pinned_to_device_count(tmp_path, monkeypatch):
    """The default key must change when the device count changes (a
    single-host artifact must not be served to a different topology)."""
    import jax as _jax
    from repro.core.variant_cache import VariantCache

    cache = VariantCache(str(tmp_path))
    assert cache.portable is False
    monkeypatch.setattr(_jax, "devices", lambda: _fake_devices(1))
    k1 = cache.entry_key("h", ("cfg",), False, {}, "args")
    monkeypatch.setattr(_jax, "devices", lambda: _fake_devices(4))
    k4 = cache.entry_key("h", ("cfg",), False, {}, "args")
    assert k1 != k4


def test_portable_cache_key_ignores_device_count_only(tmp_path, monkeypatch):
    """portable=True drops the device count but keeps the device kind, so
    single-host artifacts warm-start N identical replicas — and nothing
    else loosens."""
    import jax as _jax
    from repro.core.variant_cache import VariantCache

    cache = VariantCache(str(tmp_path), portable=True)
    monkeypatch.setattr(_jax, "devices", lambda: _fake_devices(1))
    k1 = cache.entry_key("h", ("cfg",), False, {}, "args")
    monkeypatch.setattr(_jax, "devices", lambda: _fake_devices(4))
    k4 = cache.entry_key("h", ("cfg",), False, {}, "args")
    assert k1 == k4                      # count no longer in the key
    monkeypatch.setattr(_jax, "devices",
                        lambda: _fake_devices(4, kind="OtherKind"))
    k_other = cache.entry_key("h", ("cfg",), False, {}, "args")
    assert k_other != k4                 # device *kind* stays pinned


def test_portable_and_pinned_caches_use_distinct_keys(tmp_path):
    """Flipping portability re-keys the cache (no accidental sharing
    between pinned and portable artifact stores in one directory)."""
    from repro.core.variant_cache import VariantCache

    pinned = VariantCache(str(tmp_path))
    portable = VariantCache(str(tmp_path), portable=True)
    args = ("h", ("cfg",), False, {}, "args")
    assert pinned.entry_key(*args) != portable.entry_key(*args)


def test_portable_cache_round_trip(tmp_path):
    """A portable cache still stores/loads AOT executables correctly."""
    from repro.core.variant_cache import VariantCache

    cache_dir = str(tmp_path / "portable")
    def run(cfg):
        rt = IridescentRuntime(
            async_compile=False,
            variant_cache=VariantCache(cache_dir, portable=True))
        h = rt.register("m", _mm_builder)
        h(jnp.ones((8, 8)), jnp.eye(8))
        h.specialize(cfg, wait=True)
        out = h(jnp.ones((8, 8)), jnp.eye(8))
        stats = rt.compile_stats()
        rt.shutdown()
        return np.asarray(out), stats

    o1, cold = run({"B": 4})
    o2, warm = run({"B": 4})
    assert warm["xla_compiles"] == 0
    assert warm["cache_hits"] >= 2
    np.testing.assert_allclose(o1, o2)
