"""Logical-axis sharding rules (pure logic; mesh-full tests live in
test_distributed_small.py which spawns an 8-device subprocess)."""
import pytest
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.distributed import DEFAULT_RULES, ShardingRules, logical_to_spec
from repro.training.steps import SHARDING_PROFILES


def _mesh(shape=(2, 2), axes=("data", "model")):
    # abstract mesh over the single CPU device: use jax.sharding.Mesh with
    # reshaped devices is impossible with 1 device -> use AbstractMesh
    # (constructor signature drifts across jax versions -> compat).
    return compat.abstract_mesh(shape, axes)


def test_rules_make_and_replace():
    r = ShardingRules.make({"a": "x", "b": ("x", "y"), "c": None})
    assert r.get("a") == ("x",)
    assert r.get("b") == ("x", "y")
    assert r.get("c") is None
    r2 = r.replace(a=None, c="y")
    assert r2.get("a") is None and r2.get("c") == ("y",)
    with pytest.raises(KeyError):
        r.get("missing")


def test_logical_to_spec_basic():
    m = _mesh()
    spec = logical_to_spec(("batch", None, "ffn"), (8, 3, 4), m,
                           DEFAULT_RULES)
    assert spec == P("data", None, "model")


def test_divisibility_degrades_to_replicated():
    m = _mesh()
    # dim 3 not divisible by model axis (2) -> replicated
    spec = logical_to_spec(("batch", "ffn"), (8, 3), m, DEFAULT_RULES)
    assert spec == P("data")


def test_missing_mesh_axis_is_dropped():
    m = _mesh()  # no 'pod' axis
    spec = logical_to_spec(("batch",), (8,), m, DEFAULT_RULES)
    assert spec == P("data")   # ('pod','data') filtered to ('data',)


def test_multi_axis_mapping():
    m = _mesh((2, 2, 2), ("pod", "data", "model"))
    spec = logical_to_spec(("batch", "ffn"), (8, 8), m, DEFAULT_RULES)
    assert spec == P(("pod", "data"), "model")


def test_profiles_are_distinct():
    specs = {}
    m = _mesh((2, 2, 2), ("pod", "data", "model"))
    for name, fn in SHARDING_PROFILES.items():
        rules = fn(DEFAULT_RULES)
        specs[name] = (rules.get("fsdp"), rules.get("seq"))
    assert specs["dp"][0] is None
    assert specs["fsdp"][0] == ("data",)
    assert specs["fsdp_pods"][0] == ("pod", "data")
    assert specs["seq"][1] == ("model",)


def test_trailing_nones_trimmed():
    m = _mesh()
    spec = logical_to_spec(("batch", None, None), (8, 2, 2), m,
                           DEFAULT_RULES)
    assert spec == P("data")
