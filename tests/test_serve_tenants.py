"""Multi-tenant serving: DRR weighted-fair isolation, tenant-keyed
specialization contexts, per-tenant metrics breakdowns, executor routing,
engine contract hardening, and tenant-keyed warm restarts."""
import os

import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import restore_spec_state
from repro.core import (ChangeDetector, Controller, ExhaustiveSweep,
                        IridescentRuntime)
from repro.serve import (AdmissionQueue, Completion, ContinuousBatcher,
                         ControllerGroup, DeficitRoundRobin, FCFS,
                         MultiTenantExecutor, Request, ServeEngine,
                         ServeMetrics, TenantSpec, make_scheduler,
                         make_tenant_context_fn, parse_tenant_arg)

D = 8


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt
        return self.t


# -- DRR scheduler -------------------------------------------------------------

def test_drr_service_ratio_tracks_unequal_weights():
    drr = DeficitRoundRobin({"a": 3.0, "b": 1.0}, quantum=16)
    picks = {"a": 0, "b": 0}
    for _ in range(800):
        t = drr.pick(["a", "b"])          # both always runnable
        picks[t] += 1
        drr.charge(t, 64)                 # equal-cost steps
    assert picks["a"] == pytest.approx(3 * picks["b"], rel=0.05)
    st = drr.stats()
    assert st["weights"] == {"a": 3.0, "b": 1.0}
    assert st["picks"]["a"] == picks["a"]


def test_drr_deficit_bookkeeping_replenish_charge_and_caps():
    drr = DeficitRoundRobin({"a": 2.0}, quantum=10, burst_rounds=4)
    assert drr.pick(["a"]) == "a"
    assert drr.deficit["a"] == pytest.approx(20.0)    # quantum * weight
    drr.charge("a", 5)
    assert drr.deficit["a"] == pytest.approx(15.0)
    # positive credit is capped at burst_rounds quanta...
    for _ in range(20):
        drr.pick(["a"])
    assert drr.deficit["a"] == pytest.approx(4 * 10 * 2.0)
    # ...and debt is floored at the negative cap.
    drr.charge("a", 10_000)
    assert drr.deficit["a"] == pytest.approx(-4 * 10 * 2.0)


def test_drr_idle_tenant_banks_nothing():
    drr = DeficitRoundRobin(quantum=10)
    for _ in range(10):
        drr.pick(["a"])                   # b idle the whole time
    drr.charge("a", 35)
    assert drr.pick(["a", "b"]) != "b" or drr.deficit["b"] == \
        pytest.approx(10.0)
    # b's first pick round starts from zero credit, not ten banked rounds
    assert drr.deficit["b"] <= 10.0


def test_drr_validation_and_roster():
    with pytest.raises(ValueError):
        DeficitRoundRobin(quantum=0)
    with pytest.raises(ValueError):
        DeficitRoundRobin({"a": -1.0})
    with pytest.raises(ValueError):
        DeficitRoundRobin().pick([])
    drr = make_scheduler("drr", weights={"a": 2.0})
    assert isinstance(drr, DeficitRoundRobin)
    assert drr.weight("a") == 2.0 and drr.weight("unknown") == 1.0


# -- tenant declarations -------------------------------------------------------

def test_parse_tenant_arg_grammar():
    full = parse_tenant_arg("chat=qwen3-0.6b:50:3")
    assert full == TenantSpec("chat", "qwen3-0.6b", slo_s=0.05, weight=3.0)
    assert parse_tenant_arg("bg=rwkv6-1.6b").slo_s is None
    assert parse_tenant_arg("bg=rwkv6-1.6b::2").weight == 2.0
    inherited = parse_tenant_arg("bg=rwkv6-1.6b", default_slo_ms=200.0)
    assert inherited.slo_s == pytest.approx(0.2)
    for bad in ("nameonly", "=arch", "x=", "x=a:1:2:3"):
        with pytest.raises(ValueError):
            parse_tenant_arg(bad)
    with pytest.raises(ValueError):
        TenantSpec("t", "arch", weight=0.0)
    with pytest.raises(ValueError):
        TenantSpec("t", "arch", slo_s=-1.0)


def test_make_tenant_context_fn_prefixes_keys():
    fn = make_tenant_context_fn("t", lambda a, k: ("decode", 4))
    assert fn((), {}) == ("t", "decode", 4)
    scalar = make_tenant_context_fn("t", lambda a, k: 8)
    assert scalar((), {}) == ("t", 8)
    bare = make_tenant_context_fn("t", None)
    assert bare((), {}) == ("t",)


# -- queue tenant filters ------------------------------------------------------

def test_take_where_filters_and_preserves_other_tenants():
    q = AdmissionQueue()
    reqs = [Request(tenant="a" if i % 2 else "b") for i in range(6)]
    for r in reqs:
        q.submit(r)
    assert q.waiting_tenants() == {"a", "b"}
    got = q.take(10, where=lambda r: r.tenant == "a")
    assert [r.tenant for r in got] == ["a", "a", "a"]
    assert len(q) == 3                        # b's requests untouched
    assert [r.tenant for r in q.peek_tenant("b")] == ["b", "b", "b"]
    assert len(q) == 3                        # peek does not remove
    assert q.waiting_tenants() == {"b"}


# -- batcher: one tenant per step ---------------------------------------------

def test_pack_serves_single_tenant_and_keeps_all_rows_in_flight():
    q = AdmissionQueue()
    for i in range(4):
        q.submit(Request(tenant="a" if i % 2 else "b", max_new_tokens=2))
    b = ContinuousBatcher(4, scheme="single")
    drr = DeficitRoundRobin()
    batch = b.pack([], q, drr, now=0.0)
    assert batch.tenant is not None
    assert {r.tenant for r in batch.requests} == {batch.tenant}
    # the other tenant's requests stay queued, not silently dropped
    assert q.waiting_tenants() == ({"a", "b"} - {batch.tenant})
    drr.charge(batch.tenant, 64)               # the engine charges each step
    active = list(batch.all_rows)
    second = b.pack(active, q, drr, now=0.1)
    assert second.tenant != batch.tenant       # DRR rotates to the debtor
    assert {r.rid for r in second.in_flight} >= {r.rid for r in active}


def test_pack_without_pick_serves_globally_best_ranked_tenant():
    clock = FakeClock()
    q = AdmissionQueue(clock=clock)
    first = Request(tenant="late-name-early-arrival", max_new_tokens=2)
    q.submit(first)
    clock.advance(1.0)
    q.submit(Request(tenant="a", max_new_tokens=2))
    b = ContinuousBatcher(4, scheme="single")
    batch = b.pack([], q, FCFS(), now=clock())
    assert batch.tenant == "late-name-early-arrival"   # FCFS: arrival wins
    assert batch.requests == [first]


def test_tenant_free_traffic_takes_legacy_path():
    q = AdmissionQueue()
    q.submit(Request(max_new_tokens=2))
    b = ContinuousBatcher(4, scheme="pow2")
    batch = b.pack([], q, FCFS(), now=0.0)
    assert batch.tenant is None and batch.in_flight is None
    assert batch.size == 1


# -- per-tenant metrics --------------------------------------------------------

def _completion(tenant, latency, tokens=4, within=True):
    return Completion(rid=0, prompt_tokens=1, tokens=tokens, arrival_t=0.0,
                      service_t=0.0, first_token_t=latency, finish_t=latency,
                      within_slo=within, tenant=tenant)


def test_per_tenant_breakdown_survives_state_merge_roundtrip():
    m = ServeMetrics(slo_s=1.0, tenant_slos={"a": 0.1, "b": 5.0})
    for latency in (0.01, 0.02, 0.03):
        m.observe(_completion("a", latency))
    m.observe(_completion("b", 2.0, tokens=10))
    m.observe(_completion("b", 4.0, tokens=10, within=False))
    s = m.summary()
    assert s["tenants"]["a"]["completed"] == 3
    assert s["tenants"]["a"]["slo_s"] == 0.1
    assert s["tenants"]["b"]["goodput_tokens"] == 10
    # state -> merge keeps tenant resolution and per-tenant percentiles
    merged = ServeMetrics.merge(m.state(), m.state())
    ta = merged.tenants()["a"]
    assert ta.completed == 6 and ta.percentile(50) == pytest.approx(0.02)
    tb = merged.tenants()["b"]
    assert tb.goodput_tokens == 20 and tb.slo_missed == 2
    # the parent's totals still cover everything
    assert merged.completed == 10
    assert merged.summary()["tenants"]["b"]["completed"] == 4


def test_metrics_window_travels_on_the_wire():
    big = ServeMetrics(slo_s=1.0, window=8192)
    small = ServeMetrics(slo_s=1.0, window=512)
    for m in (big, small):
        m.observe(_completion(None, 0.5))
    assert big.state()["window"] == 8192
    assert ServeMetrics.from_state(big.state()).window == 8192
    # merge keeps the biggest reservoir of the inputs
    assert ServeMetrics.merge(big, small).window == 8192
    assert ServeMetrics.merge(small.state(), big.state()).window == 8192
    # old snapshots (no window field) still load, with the old default
    legacy = {k: v for k, v in small.state().items() if k != "window"}
    assert ServeMetrics.from_state(legacy).window == 2048
    # explicit window argument still wins (caller override)
    assert ServeMetrics.from_state(big.state(), window=64).window == 64


def test_observe_shed_attributes_to_tenant():
    m = ServeMetrics()
    m.observe_shed(2, tenant="a")
    m.observe_shed(1)
    assert m.shed == 3
    assert m.tenants()["a"].shed == 2


# -- engine contract hardening -------------------------------------------------

def _toy_builder(spec):
    scale = spec.enum("scale", 1, (1, 2), guarded=False)

    def f(x, w):
        return (x @ w) * float(scale)

    return f


def _batch_ctx(args, kwargs):
    return int(args[0].shape[0])


class ToyExecutor:
    def __init__(self, handler, produced=None):
        self.handler = handler
        self.w = jnp.eye(D, dtype=jnp.float32)
        self.produced = produced
        self.retired = []

    def execute(self, batch):
        x = jnp.ones((batch.size, D), jnp.float32)
        jax.block_until_ready(self.handler(x, self.w))
        if self.produced is not None:
            return self.produced(batch)
        return None

    def retire(self, req):
        self.retired.append(req.rid)


def test_executor_length_mismatch_raises_named_error():
    rt = IridescentRuntime(async_compile=False)
    handler = rt.register("toy", _toy_builder, context_fn=_batch_ctx)
    executor = ToyExecutor(handler, produced=lambda b: [1] * (len(b.requests)
                                                             + 1))
    engine = ServeEngine(handler, None, ContinuousBatcher(4, scheme="single"),
                         FCFS(), executor=executor, queue=AdmissionQueue())
    engine.submit(Request(max_new_tokens=2))
    with pytest.raises(RuntimeError, match="ToyExecutor.*1 request"):
        engine.step()
    rt.shutdown()


def test_completion_from_request_descriptive_errors():
    with pytest.raises(ValueError, match="bypassed the admission queue"):
        Completion.from_request(Request())      # no arrival_t
    half = Request()
    half.arrival_t = 1.0
    with pytest.raises(ValueError, match="never.*retired"):
        Completion.from_request(half)           # no finish_t


def test_drain_timeout_stamps_finish_t_and_wires_draining_flag():
    rt = IridescentRuntime(async_compile=False)
    handler = rt.register("toy", _toy_builder, context_fn=_batch_ctx)
    clock = FakeClock()
    executor = ToyExecutor(handler)
    engine = ServeEngine(handler, None, ContinuousBatcher(2, scheme="single"),
                         FCFS(), executor=executor,
                         queue=AdmissionQueue(clock=clock), clock=clock)
    assert engine.stats()["draining"] is False
    long_ = Request(max_new_tokens=10 ** 6)
    engine.submit(long_)
    engine.step()
    assert not engine.drain(timeout_s=0.0)      # immediate timeout: shed
    assert engine.stats()["draining"] is True   # timed out mid-drain
    assert long_.shed and long_.finish_t is not None
    assert long_.finish_t >= long_.arrival_t    # well-formed telemetry span
    assert executor.retired == [long_.rid]
    rt.shutdown()


def test_drain_completes_clears_draining_flag():
    rt = IridescentRuntime(async_compile=False)
    handler = rt.register("toy", _toy_builder, context_fn=_batch_ctx)
    engine = ServeEngine(handler, None, ContinuousBatcher(2, scheme="single"),
                         FCFS(), executor=ToyExecutor(handler),
                         queue=AdmissionQueue())
    engine.submit(Request(max_new_tokens=2))
    assert engine.drain(timeout_s=10.0)
    assert engine.stats()["draining"] is False
    rt.shutdown()


# -- multi-tenant engine -------------------------------------------------------

def _tenant_engine(scheduler, rt, tag=""):
    """Two toy tenants behind one engine: 'a' sparse, 'b' greedy."""
    ha = rt.register(f"toy[a]{tag}", _toy_builder,
                     context_fn=make_tenant_context_fn("a", _batch_ctx))
    hb = rt.register(f"toy[b]{tag}", _toy_builder,
                     context_fn=make_tenant_context_fn("b", _batch_ctx))
    executor = MultiTenantExecutor({"a": ToyExecutor(ha),
                                    "b": ToyExecutor(hb)})
    engine = ServeEngine(ha, None, ContinuousBatcher(2, scheme="single"),
                         scheduler, executor=executor, queue=AdmissionQueue())
    return engine, ha, hb


def _steps_until_tenant_a_done(engine, n_greedy=20):
    for _ in range(n_greedy):
        engine.submit(Request(tenant="b", max_new_tokens=4))
    a_reqs = [Request(tenant="a", max_new_tokens=2) for _ in range(2)]
    for r in a_reqs:
        engine.submit(r)
    steps = 0
    while not all(r.done for r in a_reqs):
        engine.step()
        steps += 1
        assert steps < 500
    return steps


def test_drr_isolates_sparse_tenant_from_greedy_flood():
    rt = IridescentRuntime(async_compile=False)
    drr_engine, *_ = _tenant_engine(DeficitRoundRobin(), rt)
    drr_steps = _steps_until_tenant_a_done(drr_engine)
    fcfs_engine, *_ = _tenant_engine(FCFS(), rt, tag="/fcfs")
    fcfs_steps = _steps_until_tenant_a_done(fcfs_engine)
    # FCFS serves the flood's backlog first; DRR alternates fairly.
    assert drr_steps < fcfs_steps
    assert fcfs_steps > 2 * drr_steps
    stats = drr_engine.stats()
    assert set(stats["tenant_steps"]) == {"a", "b"}
    assert stats["scheduler"]["picks"]["b"] > 0
    rt.shutdown()


def test_tenant_contexts_are_disjoint_per_tenant():
    rt = IridescentRuntime(async_compile=False)
    engine, ha, hb = _tenant_engine(DeficitRoundRobin(), rt)
    for tenant in ("a", "b"):
        engine.submit(Request(tenant=tenant, max_new_tokens=2))
    engine.run()
    assert ("a", 2) in ha.contexts() and ("b", 2) in hb.contexts()
    served = engine.metrics.summary()["tenants"]
    assert served["a"]["completed"] == 1 and served["b"]["completed"] == 1
    rt.shutdown()


def test_tenant_slo_default_applied_at_retire():
    rt = IridescentRuntime(async_compile=False)
    clock = FakeClock()
    ha = rt.register("toy[a]", _toy_builder,
                     context_fn=make_tenant_context_fn("a", _batch_ctx))
    executor = MultiTenantExecutor({"a": ToyExecutor(ha)})
    got = []
    engine = ServeEngine(ha, None, ContinuousBatcher(2, scheme="single"),
                         DeficitRoundRobin(), executor=executor,
                         queue=AdmissionQueue(clock=clock), clock=clock,
                         slo_s=100.0, tenant_slos={"a": 0.5},
                         on_completion=got.append)
    engine.submit(Request(tenant="a", max_new_tokens=1))
    clock.advance(1.0)                          # over the tenant SLO
    engine.step()
    (comp,) = got
    assert comp.tenant == "a"
    assert not comp.within_slo                  # 1.0s > tenant's 0.5s SLO
    rt.shutdown()


def test_multitenant_executor_routing_and_validation():
    rt = IridescentRuntime(async_compile=False)
    ha = rt.register("toy[a]", _toy_builder, context_fn=_batch_ctx)
    with pytest.raises(ValueError):
        MultiTenantExecutor({})
    ex = MultiTenantExecutor({"a": ToyExecutor(ha)})
    from repro.serve import PackedBatch
    with pytest.raises(KeyError, match="no executor for tenant"):
        ex.execute(PackedBatch(requests=[Request(tenant="z")], size=1,
                               joined=[], scheme="single", tenant="z"))

    class Phased(ToyExecutor):
        phased = True

    with pytest.raises(ValueError, match="agree on phased"):
        MultiTenantExecutor({"a": ToyExecutor(ha), "b": Phased(ha)})
    rt.shutdown()


def test_controller_group_aggregates_and_validates():
    rt = IridescentRuntime(async_compile=False)
    ha = rt.register("toy[a]", _toy_builder,
                     context_fn=make_tenant_context_fn("a", _batch_ctx))
    hb = rt.register("toy[b]", _toy_builder,
                     context_fn=make_tenant_context_fn("b", _batch_ctx))
    sweep = lambda: ExhaustiveSweep([{"scale": 2}, {"scale": 1}])
    ca = Controller(ha, sweep, dwell=2, wait_compiles=True, prefetch=0,
                    change_detector=lambda: ChangeDetector(float("inf")))
    cb = Controller(hb, sweep, dwell=2, wait_compiles=True, prefetch=0,
                    change_detector=lambda: ChangeDetector(float("inf")))
    group = ControllerGroup([(ha, ca), (hb, cb)])
    assert group.controllers == {"toy[a]": ca, "toy[b]": cb}
    with pytest.raises(ValueError):
        ControllerGroup([])
    with pytest.raises(ValueError):
        ControllerGroup([(ha, ca), (ha, cb)])
    w = jnp.eye(D, dtype=jnp.float32)
    x = jnp.ones((2, D), jnp.float32)
    for _ in range(12):
        ha(x, w), hb(x, w)
        group.step()
    assert group.settled()
    assert set(group.best_configs()) == {"toy[a]", "toy[b]"}
    assert ("a", 2) in group.contexts() and ("b", 2) in group.contexts()
    rt.shutdown()


# -- warm restart with tenant-keyed contexts -----------------------------------

def _tenant_restart_stack(tmp_path, restore=False):
    cache_dir = str(tmp_path / "state")
    rt = IridescentRuntime(async_compile=False,
                           variant_cache=os.path.join(cache_dir, "variants"))
    ha = rt.register("toy[a]", _toy_builder,
                     context_fn=make_tenant_context_fn("a", _batch_ctx))
    hb = rt.register("toy[b]", _toy_builder,
                     context_fn=make_tenant_context_fn("b", _batch_ctx))
    restored = False
    if restore:
        restored = restore_spec_state(
            os.path.join(cache_dir, "spec_state.json"), rt, wait=True)
    sweep = lambda: ExhaustiveSweep([{"scale": 2}, {"scale": 1}])
    mk = lambda h: Controller(
        h, sweep, dwell=3, wait_compiles=True, prefetch=0,
        change_detector=lambda: ChangeDetector(float("inf")))
    group = ControllerGroup([(ha, mk(ha)), (hb, mk(hb))])
    executor = MultiTenantExecutor({"a": ToyExecutor(ha),
                                    "b": ToyExecutor(hb)})
    engine = ServeEngine(ha, group, ContinuousBatcher(2, scheme="single"),
                         DeficitRoundRobin(), executor=executor,
                         queue=AdmissionQueue())
    return cache_dir, rt, ha, hb, group, engine, restored


def _serve_both_tenants(engine, rounds=60):
    for _ in range(rounds):
        for tenant in ("a", "b"):
            while sum(1 for r in engine.active if r.tenant == tenant) + \
                    len(engine.queue.peek_tenant(tenant)) < 2:
                engine.submit(Request(tenant=tenant, max_new_tokens=2))
        engine.step()


def test_tenant_contexts_restore_from_spec_state_with_zero_recompiles(
        tmp_path):
    (cache_dir, rt, ha, hb, group, engine,
     _) = _tenant_restart_stack(tmp_path)
    _serve_both_tenants(engine)
    assert group.settled()
    tuned = {name: {k: dict(cfg) for k, cfg in ctl.best_configs().items()}
             for name, ctl in group.controllers.items()}
    assert tuned["toy[a]"][("a", 2)] and tuned["toy[b]"][("b", 2)]
    assert rt.compile_stats()["xla_compiles"] > 0
    engine.shutdown(state_dir=cache_dir)
    assert os.path.exists(os.path.join(cache_dir, "spec_state.json"))

    # -- warm restart: every tenant context seeds, nothing recompiles ------
    (cache_dir, rt2, ha2, hb2, group2, engine2,
     restored) = _tenant_restart_stack(tmp_path, restore=True)
    assert restored
    assert ha2._seeded and hb2._seeded     # both tenants' contexts seeded
    _serve_both_tenants(engine2, rounds=20)
    warm = rt2.compile_stats()
    assert warm["xla_compiles"] == 0          # all variants from the cache
    assert warm["cache_hits"] > 0
    for name, ctl in group2.controllers.items():
        key = ("a", 2) if name == "toy[a]" else ("b", 2)
        assert ctl.settled(context=key)
        assert dict(ctl.best_configs()[key]) == tuned[name][key]
    rt2.shutdown()
