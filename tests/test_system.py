"""End-to-end behaviour tests for the paper's system: the full Iridescent
loop (declare space -> explore online -> exploit -> adapt) driving real
jitted handlers, plus guard-corrected serving."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (ChangeDetector, ExhaustiveSweep, Explorer,
                        IridescentRuntime, Phase, guards)
from repro.core.fastpath import build_table, make_fastpath


def test_full_loop_converges_and_adapts():
    """The paper's Fig 2/7 scenario in miniature: a handler whose optimal
    configuration depends on the workload; the explorer finds the optimum,
    then re-explores after a workload change."""
    rt = IridescentRuntime(async_compile=False)

    def build(spec):
        b = spec.enum("B", 1, (1, 4))

        def handler(x):
            return (x * b).sum()

        return handler

    h = rt.register("h", build)
    h(jnp.ones(8))

    # synthetic metric: config B=4 is 3x "faster" in workload phase 0,
    # B=1 wins in phase 1 (emulates Table 1's hw/workload dependence).
    phase = {"v": 0}

    def metric():
        b = h.active_config().get("B", 1)
        speed = {0: {1: 1.0, 4: 3.0}, 1: {1: 5.0, 4: 0.5}}
        return speed[phase["v"]].get(b if b else 1, 1.0)

    ex = Explorer(h, ExhaustiveSweep.from_space(h.spec_space(), ["B"]),
                  dwell=3, metric_fn=metric,
                  change_detector=ChangeDetector(0.25, warmup=0))
    for _ in range(40):
        h(jnp.ones(8))
        ex.step()
    assert ex.phase is Phase.EXPLOIT
    assert h.active_config()["B"] == 4

    phase["v"] = 1   # workload change -> metric drops -> re-explore
    for _ in range(80):
        h(jnp.ones(8))
        ex.step()
    assert ex.explorations >= 1
    assert h.active_config()["B"] == 1


def test_guarded_specialization_serving():
    """Fast-path-specialized lookup handler stays correct on misses and the
    policy can read the instrumentation statistics (paper §5 two phases)."""
    rt = IridescentRuntime(async_compile=False)

    def generic(xb):
        xb = jnp.atleast_2d(xb)
        return (xb.astype(jnp.float32) * 2 + 1).sum(-1, keepdims=True)

    rt.add_custom_spec(
        "fastpath",
        lambda payload: make_fastpath(
            generic, payload, skip_generic_when_all_hit=True))

    def build(spec):
        fp = spec.custom("hot", "fastpath")
        return fp if fp is not None else generic

    h = rt.register("lookup", build)
    x = jnp.asarray(np.array([[3], [9], [40]], np.int64))
    expect = np.asarray(generic(x))
    np.testing.assert_allclose(h(x), expect)

    # instrumentation phase -> build table -> specialize (paper §5 phases)
    h.enable_instrumentation(rate=1.0, collectors={
        "hot": lambda a, k: int(np.asarray(a[0])[0, 0])})
    for _ in range(5):
        h(x)
    tbl = build_table(h.spec_space().observed, "hot", n=2,
                      generic_fn=generic)
    assert tbl is not None
    h.disable_instrumentation()
    h.specialize({"hot": tbl}, wait=True)
    np.testing.assert_allclose(h(x), expect)       # hits + misses both right


def test_checkpoint_restart_training(tmp_path):
    """Fault tolerance: kill/restart mid-training resumes identically."""
    from repro import configs
    from repro.checkpoint import CheckpointManager
    from repro.core.specializer import specialize_builder
    from repro.data import SyntheticLM
    from repro.models import transformer as model
    from repro.optim import OptConfig, init_opt_state
    from repro.training import make_train_builder

    cfg = configs.get_reduced("qwen3-0.6b").replace(compute_dtype="float32")
    opt_cfg = OptConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    step = jax.jit(specialize_builder(
        make_train_builder(cfg, opt_cfg, kernel_impl="xla"), {}).fn)
    ds = SyntheticLM(cfg.vocab_size, batch=2, seq_len=16, seed=1, prefetch=0)

    params = model.init_params(jax.random.PRNGKey(0), cfg)
    state = {"params": params, "opt": init_opt_state(params, opt_cfg)}
    mgr = CheckpointManager(str(tmp_path), keep=2)

    for i in range(4):
        state, _ = step(state, ds.batch_at(i))
    mgr.save(4, state, extra_meta={"data_step": 4}, block=True)
    for i in range(4, 6):
        state, m = step(state, ds.batch_at(i))
    loss_direct = float(m["loss"])

    # "crash" -> restore -> replay
    restored, meta = mgr.restore(state)
    st2 = restored
    for i in range(meta["data_step"], 6):
        st2, m2 = step(st2, ds.batch_at(i))
    assert abs(float(m2["loss"]) - loss_direct) < 1e-5
    for a, b in zip(jax.tree_util.tree_leaves(state["params"]),
                    jax.tree_util.tree_leaves(st2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
