"""Policies + explorer lifecycle."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ChangeDetector, ContextualBandit, CoordinateDescent,
                        CostAwareUCB, EpsilonGreedy, ExhaustiveSweep,
                        ScoreBoard, SuccessiveHalving)
from repro.core.points import EnumPoint, SpecSpace


def _space(axes: dict) -> SpecSpace:
    s = SpecSpace()
    for label, choices in axes.items():
        s.register(EnumPoint(label, choices[0], choices=tuple(choices)))
    return s


def _drive(policy, metric_fn):
    while True:
        cfg = policy.propose()
        if cfg is None:
            return policy.best()
        policy.observe(cfg, metric_fn(cfg))


def test_exhaustive_finds_argmax():
    space = _space({"b": (1, 2, 4, 8)})
    pol = ExhaustiveSweep.from_space(space, labels=["b"])
    best, metric = _drive(pol, lambda c: -abs(c["b"] - 4))
    assert best["b"] == 4 and metric == 0


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 100), min_size=2, max_size=6, unique=True))
def test_property_exhaustive_optimal(vals):
    space = _space({"x": tuple(vals)})
    pol = ExhaustiveSweep.from_space(space, labels=["x"])
    best, _ = _drive(pol, lambda c: float(c["x"]))
    assert best["x"] == max(vals)


def test_coordinate_descent_separable():
    space = _space({"a": (0, 1, 2, 3), "b": (0, 1, 2, 3), "c": (0, 1, 2)})
    pol = CoordinateDescent(space)
    best, _ = _drive(pol, lambda c: -((c.get("a") or 0) - 2) ** 2
                     - ((c.get("b") or 0) - 3) ** 2
                     - ((c.get("c") or 0) - 1) ** 2)
    assert (best["a"], best["b"], best["c"]) == (2, 3, 1)


def test_coordinate_descent_cheaper_than_exhaustive():
    space = _space({"a": tuple(range(8)), "b": tuple(range(8)),
                    "c": tuple(range(8))})
    pol = CoordinateDescent(space)
    evals = 0
    while True:
        cfg = pol.propose()
        if cfg is None:
            break
        evals += 1
        pol.observe(cfg, -(cfg.get("a") or 0))
    assert evals < 8 ** 3 / 4   # far below the 512-config product space


def test_epsilon_greedy_exploits():
    space = _space({"x": (1, 2, 3)})
    pol = EpsilonGreedy(space.configs(labels=["x"]), eps=0.0, seed=1)
    for _ in range(10):
        cfg = pol.propose()
        pol.observe(cfg, float(cfg["x"] == 2))
    assert pol.best()[0]["x"] == 2
    assert pol.propose()["x"] == 2   # pure exploitation now


def test_successive_halving_converges():
    cands = [{"x": i} for i in range(8)]
    pol = SuccessiveHalving(cands)
    best, _ = _drive(pol, lambda c: float(c["x"]))
    assert best["x"] == 7


def test_change_detector():
    cd = ChangeDetector(threshold=0.25, warmup=2)
    for _ in range(8):
        assert not cd.update(100.0)
    assert cd.update(10.0)        # -90% -> change
    for _ in range(8):
        assert not cd.update(10.0)   # re-baselined
    assert cd.update(20.0)        # +100% -> change


def test_change_detector_ignores_noise():
    cd = ChangeDetector(threshold=0.25, warmup=2)
    vals = [100, 102, 98, 101, 99, 103, 97, 100]
    assert not any(cd.update(v) for v in vals)


# --- peek(n) across all shipped policies ----------------------------------------

def test_exhaustive_peek_does_not_consume():
    pol = ExhaustiveSweep([{"x": i} for i in range(4)])
    assert pol.peek(2) == [{"x": 0}, {"x": 1}]
    assert pol.peek(10) == [{"x": i} for i in range(4)]   # clamped
    assert pol.propose() == {"x": 0}                      # unchanged by peek
    assert pol.peek(1) == [{"x": 1}]


def test_coordinate_descent_peek_stops_at_axis_edge():
    """Only the remainder of the current axis is metric-independent: the
    next axis re-pins to whatever incumbent wins this one."""
    space = _space({"a": (0, 1, 2), "b": (0, 1)})
    pol = CoordinateDescent(space)
    first = pol.propose()
    upcoming = pol.peek(10)
    assert upcoming                                        # rest of axis 'a'
    assert all(set(c) == set(first) for c in upcoming)
    assert all(c["b"] == first["b"] for c in upcoming)     # axis 'b' pinned
    # peeked configs come back from propose() in the same order
    for expect in upcoming:
        assert pol.propose() == expect


def test_epsilon_greedy_peek_covers_unseen_only():
    cands = [{"x": i} for i in range(3)]
    pol = EpsilonGreedy(cands, eps=0.0, seed=0)
    assert pol.peek(5) == cands                            # initial sweep
    for cfg in cands:
        assert pol.propose() == cfg
        pol.observe(cfg, float(cfg["x"]))
    assert pol.peek(5) == []      # exploitation: next pick is metric-driven


def test_successive_halving_peek_stops_at_rung_edge():
    cands = [{"x": i} for i in range(4)]
    pol = SuccessiveHalving(cands)
    assert pol.peek(10) == cands                           # full first rung
    for cfg in cands:
        assert pol.propose() == cfg
        pol.observe(cfg, float(cfg["x"]))
    assert pol.peek(10) == []     # survivors depend on this rung's scores


def test_contextual_bandit_peek_covers_unpulled_arms_only():
    pol = ContextualBandit([{"x": i} for i in range(3)], rounds=10)
    assert pol.peek(5) == [{"x": 0}, {"x": 1}, {"x": 2}]
    cfg = pol.propose()
    pol.observe(cfg, 1.0)
    assert pol.peek(5) == [{"x": 1}, {"x": 2}]
    for _ in range(2):
        pol.observe(pol.propose(), 1.0)
    assert pol.peek(5) == []      # all arms pulled: UCB is metric-driven


def test_peek_returns_copies():
    pol = ExhaustiveSweep([{"x": 0}])
    peeked = pol.peek(1)[0]
    peeked["x"] = 99
    assert pol.propose() == {"x": 0}                       # not aliased


# --- ScoreBoard / best() tie-breaking -------------------------------------------

def test_scoreboard_tie_breaks_to_first_observed():
    board = ScoreBoard()
    board.observe({"x": "late_tie"}, 1.0)
    board.observe({"x": "winner"}, 2.0)
    board.observe({"x": "tie"}, 2.0)                       # same metric, later
    assert board.best()[0] == {"x": "winner"}


def test_scoreboard_refresh_keeps_insertion_order():
    board = ScoreBoard()
    board.observe({"x": "a"}, 2.0)
    board.observe({"x": "b"}, 2.0)
    board.observe({"x": "a"}, 2.0)     # re-observation must not demote 'a'
    assert board.best()[0] == {"x": "a"}


@pytest.mark.parametrize("make", [
    lambda c: ExhaustiveSweep(c),
    lambda c: EpsilonGreedy(c, eps=0.0, seed=0),
    lambda c: SuccessiveHalving(c),
    lambda c: ContextualBandit(c, rounds=len(c)),
    lambda c: CostAwareUCB(c, rounds=len(c)),
])
def test_best_tie_break_deterministic_across_policies(make):
    """All shipped policies break best() ties to the earliest-observed
    candidate (candidate order), so equal-metric sweeps are reproducible."""
    cands = [{"x": i} for i in range(4)]
    pol = make(cands)
    while True:
        cfg = pol.propose()
        if cfg is None:
            break
        pol.observe(cfg, 1.0)                              # all metrics equal
        if isinstance(pol, EpsilonGreedy) and pol.peek(1) == []:
            break                  # eps=0 exploitation loops forever
    assert pol.best()[0] == cands[0]


def test_coordinate_descent_best_tie_keeps_incumbent():
    space = _space({"a": (0, 1, 2)})
    pol = CoordinateDescent(space)
    first = pol.propose()
    pol.observe(first, 1.0)
    while True:
        cfg = pol.propose()
        if cfg is None:
            break
        pol.observe(cfg, 1.0)      # ties: strictly-greater required to adopt
    assert pol.best()[0] == first


# -- Thompson sampling ---------------------------------------------------------

def test_thompson_finds_argmax_gaussian():
    from repro.core import ThompsonSampling
    cands = [{"b": b} for b in (1, 2, 4, 8)]
    pol = ThompsonSampling(cands, seed=0, rounds=40)
    best, metric = _drive(pol, lambda c: float(c["b"]))
    assert best == {"b": 8} and metric == pytest.approx(8.0)


def test_thompson_beta_posterior_converges():
    from repro.core import ThompsonSampling
    cands = [{"arm": i} for i in range(3)]
    pol = ThompsonSampling(cands, seed=1, rounds=60, posterior="beta")
    rewards = {0: 0.1, 1: 0.9, 2: 0.3}
    best, _ = _drive(pol, lambda c: rewards[c["arm"]])
    assert best == {"arm": 1}
    stats = {s["config"]["arm"]: s["pulls"] for s in pol.arm_stats()}
    assert stats[1] > stats[0] and stats[1] > stats[2]  # it exploited arm 1


def test_thompson_deterministic_under_seed():
    from repro.core import ThompsonSampling
    cands = [{"x": i} for i in range(4)]

    def trace(seed):
        pol = ThompsonSampling(cands, seed=seed, rounds=24)
        out = []
        while True:
            cfg = pol.propose()
            if cfg is None:
                return out
            pol.observe(cfg, float(cfg["x"] % 3))
            out.append(cfg["x"])

    assert trace(7) == trace(7)               # same seed -> same proposals
    assert trace(7) != trace(8)               # different stream explores
    from copy import deepcopy
    pol = ThompsonSampling(cands, seed=7)
    clone = deepcopy(pol)                     # Controller's factory protocol
    clone.reset()
    assert [clone.propose() for _ in range(4)] == \
        [pol.propose() for _ in range(4)]


def test_thompson_peek_covers_unseen_without_burning_rng():
    from repro.core import ThompsonSampling
    cands = [{"x": i} for i in range(3)]
    pol = ThompsonSampling(cands, seed=0, rounds=12)
    assert pol.peek(2) == cands[:2]
    before = pol._rng.getstate()
    pol.peek(3)
    assert pol._rng.getstate() == before      # peeking consumed no draws
    for cfg in cands:
        pol.observe(cfg, 1.0)
        pol.propose()
    assert pol.peek(2) == []                  # all arms pulled


def test_thompson_invalid_args():
    from repro.core import ThompsonSampling
    with pytest.raises(ValueError):
        ThompsonSampling([])
    with pytest.raises(ValueError):
        ThompsonSampling([{"x": 1}], posterior="dirichlet")


# -- cost-aware UCB -------------------------------------------------------------

def _costs(table):
    return lambda cfg: table.get(cfg["x"])


def test_cost_aware_finds_argmax():
    cands = [{"x": i} for i in range(4)]
    pol = CostAwareUCB(cands, rounds=32,
                       cost_fn=_costs({0: 0.5, 1: 0.5, 2: 0.5, 3: 0.5}))
    best, metric = _drive(pol, lambda c: float(c["x"]))
    assert best == {"x": 3} and metric == 3.0


def test_cost_aware_explores_cheapest_first():
    cands = [{"x": "pricey"}, {"x": "cheap"}, {"x": "mid"}]
    pol = CostAwareUCB(cands, rounds=12,
                       cost_fn=_costs({"pricey": 5.0, "cheap": 0.1,
                                       "mid": 1.0}))
    order = []
    for _ in range(3):
        cfg = pol.propose()
        order.append(cfg["x"])
        pol.observe(cfg, 1.0)
    assert order == ["cheap", "mid", "pricey"]


def test_cost_aware_unknown_cost_keeps_candidate_order():
    # cost_fn=None (or returning None) => no penalty: the pull-once phase
    # degrades to ContextualBandit's candidate-order sweep.
    cands = [{"x": i} for i in range(3)]
    pol = CostAwareUCB(cands, rounds=6)
    order = []
    for _ in range(3):
        cfg = pol.propose()
        order.append(cfg["x"])
        pol.observe(cfg, 1.0)
    assert order == [0, 1, 2]


def test_cost_aware_tight_budget_skips_most_expensive():
    # rounds tighter than the arm count: the arms left unmeasured are the
    # most expensive ones (the veto gate's all-or-nothing, made gradual).
    cands = [{"x": i} for i in range(4)]
    pol = CostAwareUCB(cands, rounds=2,
                       cost_fn=_costs({0: 4.0, 1: 1.0, 2: 3.0, 3: 2.0}))
    seen = []
    while True:
        cfg = pol.propose()
        if cfg is None:
            break
        seen.append(cfg["x"])
        pol.observe(cfg, 1.0)
    assert seen == [1, 3]          # two cheapest; x=0 and x=2 never built


def test_cost_aware_penalty_sunk_after_observe():
    cands = [{"x": 0}, {"x": 1}]
    pol = CostAwareUCB(cands, rounds=8, cost_fn=_costs({0: 2.0, 1: 2.0}))
    stats = {s["config"]["x"]: s for s in pol.arm_stats()}
    assert stats[0]["penalty"] > 0 and stats[1]["penalty"] > 0
    for cfg in cands:
        pol.observe(cfg, 1.0)
    stats = {s["config"]["x"]: s for s in pol.arm_stats()}
    assert stats[0]["penalty"] == 0 and stats[1]["penalty"] == 0


def test_cost_aware_built_fn_zeroes_penalty():
    # A cache hit (built_fn True) is free even before any observation —
    # the warm-start story: remotely compiled arms explore without penalty.
    cands = [{"x": "hot"}, {"x": "cold"}]
    pol = CostAwareUCB(cands, rounds=8,
                       cost_fn=_costs({"hot": 9.0, "cold": 1.0}),
                       built_fn=lambda cfg: cfg["x"] == "hot")
    assert pol.propose() == {"x": "hot"}   # despite the 9x estimate
    stats = {s["config"]["x"]: s for s in pol.arm_stats()}
    assert stats["hot"]["penalty"] == 0 and stats["cold"]["penalty"] > 0


def test_cost_aware_peek_covers_cheap_phase_only():
    cands = [{"x": i} for i in range(3)]
    pol = CostAwareUCB(cands, rounds=10,
                       cost_fn=_costs({0: 3.0, 1: 1.0, 2: 2.0}))
    assert pol.peek(5) == [{"x": 1}, {"x": 2}, {"x": 0}]   # cheapest-first
    peeked = pol.peek(1)[0]
    peeked["x"] = 99                                       # copies, no alias
    cfg = pol.propose()
    assert cfg == {"x": 1}
    pol.observe(cfg, 1.0)
    assert pol.peek(5) == [{"x": 2}, {"x": 0}]
    for _ in range(2):
        pol.observe(pol.propose(), 1.0)
    assert pol.peek(5) == []       # pulled arms: scores are metric-driven


def test_cost_aware_auto_rounds_and_validation():
    pol = CostAwareUCB([{"x": 0}, {"x": 1}])
    assert pol.rounds == 8                                 # 4x arms
    with pytest.raises(ValueError):
        CostAwareUCB([])
    with pytest.raises(ValueError):
        CostAwareUCB([{"x": 0}], dwell_s=0.0)


def test_cost_aware_factory_deepcopy():
    from copy import deepcopy
    pol = CostAwareUCB([{"x": 0}, {"x": 1}], rounds=4,
                       cost_fn=_costs({0: 1.0, 1: 2.0}))
    pol.observe({"x": 0}, 5.0)
    clone = deepcopy(pol)          # Controller policy-factory protocol
    clone.reset()
    assert clone.best() == (None, -math.inf)
    assert pol.best()[0] == {"x": 0}
