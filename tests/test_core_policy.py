"""Policies + explorer lifecycle."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ChangeDetector, CoordinateDescent, EpsilonGreedy,
                        ExhaustiveSweep, SuccessiveHalving)
from repro.core.points import EnumPoint, SpecSpace


def _space(axes: dict) -> SpecSpace:
    s = SpecSpace()
    for label, choices in axes.items():
        s.register(EnumPoint(label, choices[0], choices=tuple(choices)))
    return s


def _drive(policy, metric_fn):
    while True:
        cfg = policy.propose()
        if cfg is None:
            return policy.best()
        policy.observe(cfg, metric_fn(cfg))


def test_exhaustive_finds_argmax():
    space = _space({"b": (1, 2, 4, 8)})
    pol = ExhaustiveSweep.from_space(space, labels=["b"])
    best, metric = _drive(pol, lambda c: -abs(c["b"] - 4))
    assert best["b"] == 4 and metric == 0


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 100), min_size=2, max_size=6, unique=True))
def test_property_exhaustive_optimal(vals):
    space = _space({"x": tuple(vals)})
    pol = ExhaustiveSweep.from_space(space, labels=["x"])
    best, _ = _drive(pol, lambda c: float(c["x"]))
    assert best["x"] == max(vals)


def test_coordinate_descent_separable():
    space = _space({"a": (0, 1, 2, 3), "b": (0, 1, 2, 3), "c": (0, 1, 2)})
    pol = CoordinateDescent(space)
    best, _ = _drive(pol, lambda c: -((c.get("a") or 0) - 2) ** 2
                     - ((c.get("b") or 0) - 3) ** 2
                     - ((c.get("c") or 0) - 1) ** 2)
    assert (best["a"], best["b"], best["c"]) == (2, 3, 1)


def test_coordinate_descent_cheaper_than_exhaustive():
    space = _space({"a": tuple(range(8)), "b": tuple(range(8)),
                    "c": tuple(range(8))})
    pol = CoordinateDescent(space)
    evals = 0
    while True:
        cfg = pol.propose()
        if cfg is None:
            break
        evals += 1
        pol.observe(cfg, -(cfg.get("a") or 0))
    assert evals < 8 ** 3 / 4   # far below the 512-config product space


def test_epsilon_greedy_exploits():
    space = _space({"x": (1, 2, 3)})
    pol = EpsilonGreedy(space.configs(labels=["x"]), eps=0.0, seed=1)
    for _ in range(10):
        cfg = pol.propose()
        pol.observe(cfg, float(cfg["x"] == 2))
    assert pol.best()[0]["x"] == 2
    assert pol.propose()["x"] == 2   # pure exploitation now


def test_successive_halving_converges():
    cands = [{"x": i} for i in range(8)]
    pol = SuccessiveHalving(cands)
    best, _ = _drive(pol, lambda c: float(c["x"]))
    assert best["x"] == 7


def test_change_detector():
    cd = ChangeDetector(threshold=0.25, warmup=2)
    for _ in range(8):
        assert not cd.update(100.0)
    assert cd.update(10.0)        # -90% -> change
    for _ in range(8):
        assert not cd.update(10.0)   # re-baselined
    assert cd.update(20.0)        # +100% -> change


def test_change_detector_ignores_noise():
    cd = ChangeDetector(threshold=0.25, warmup=2)
    vals = [100, 102, 98, 101, 99, 103, 97, 100]
    assert not any(cd.update(v) for v in vals)
