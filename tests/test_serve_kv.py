"""Paged KV runtime + phase-disaggregated execution.

Covers the PR's serve-path invariants:

* page-allocator properties (hypothesis): no double free, no page ever
  shared between live requests, LIFO free-list reuse after retire,
* materialize/harvest round trips keep per-request state isolated,
* **determinism**: requests decoding interleaved through the phased
  executor produce exactly the tokens they produce when served alone
  (and the same under paged vs contiguous KV geometry),
* tuple context keys (``(phase, bucket)``) survive spec_state.json
  save -> restore losslessly, and a warm restart resumes distinct
  per-phase configs with zero XLA recompiles,
* schedulers account for remaining *prefill* in job size.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import restore_spec_state, save_spec_state
from repro.core import IridescentRuntime
from repro.core.runtime import decode_context_key, encode_context_key
from repro.serve import (AdmissionQueue, ContinuousBatcher, DeadlineAware,
                         FCFS, PagedKV, PageError, PagePool, PhasedExecutor,
                         Request, ServeEngine, ServeMetrics,
                         ShortestJobFirst)
from repro.training import phase_context_fn

MAX_LEN = 16
VOCAB = 7


def _template(width: int = 3):
    return {"k": jnp.zeros((1, MAX_LEN, width), jnp.float32),
            "state": jnp.zeros((1, width), jnp.float32),
            "tick": jnp.zeros((), jnp.float32)}


AXES = {"k": ("batch", "seq_kv", "model"),
        "state": ("batch", "model"),
        "tick": ()}


def make_kv(page_size=4, layout="paged", capacity=8 * MAX_LEN, width=3):
    return PagedKV(_template(width), AXES, max_len=MAX_LEN,
                   capacity_tokens=capacity, page_size=page_size,
                   layout=layout)


# -- page allocator properties --------------------------------------------------

@settings(max_examples=20)
@given(st.integers(1, 12), st.integers(1, 8))
def test_pool_allocs_are_unique_until_freed(num_pages, page_size):
    pool = PagePool(num_pages, page_size)
    got = [pool.alloc() for _ in range(num_pages)]
    assert sorted(got) == list(range(num_pages))   # each page handed out once
    with pytest.raises(PageError):
        pool.alloc()                               # exhausted
    for pid in got:
        pool.free(pid)
    assert pool.free_pages == num_pages


def test_pool_double_free_and_foreign_page_raise():
    pool = PagePool(4, 2)
    pid = pool.alloc()
    pool.free(pid)
    with pytest.raises(PageError):
        pool.free(pid)                             # double free
    with pytest.raises(PageError):
        pool.free(99)                              # never belonged here


def test_pool_free_list_reuse_is_lifo():
    pool = PagePool(8, 2)
    a, b = pool.alloc(), pool.alloc()
    pool.free(a)
    pool.free(b)
    assert pool.alloc() == b                       # most recently freed
    assert pool.alloc() == a


@settings(max_examples=15)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 2)),
                min_size=1, max_size=40))
def test_no_page_shared_between_live_requests(ops):
    """Random join/harvest/retire interleavings: live requests' page sets
    stay disjoint, and retiring everything returns every page."""
    kv = make_kv(page_size=4)
    live: dict[str, int] = {}                      # rid -> tokens written
    for slot, action in ops:
        rid = f"r{slot}"
        if rid not in live:
            kv.join(rid)
            live[rid] = 0
        elif action == 0 and live[rid] + 2 <= MAX_LEN:
            cache, lengths = kv.materialize([rid], 1)
            assert int(lengths[0]) == live[rid]
            kv.harvest([rid], cache, [2])
            live[rid] += 2
        elif action == 1:
            kv.retire(rid)
            del live[rid]
        tables = {r: kv.table(r) for r in live}
        owned = [p for t in tables.values() for p in t.pages]
        assert len(owned) == len(set(owned)), "page shared across requests"
    for rid in list(live):
        kv.retire(rid)
    for pool in kv.stats()["pools"].values():
        assert pool["live_pages"] == 0
        assert pool["allocs"] == pool["frees"]


def test_retired_pages_are_reused_by_next_join():
    kv = make_kv(page_size=4)
    kv.join("a")
    cache, _ = kv.materialize(["a"], 1)
    kv.harvest(["a"], cache, [8])                  # 2 pages
    pages_a = list(kv.table("a").pages)
    kv.retire("a")
    kv.join("b")
    cache, _ = kv.materialize(["b"], 1)
    kv.harvest(["b"], cache, [8])
    assert set(kv.table("b").pages) == set(pages_a)   # free list reused


def test_harvest_roundtrip_isolates_rows():
    """Distinct values written for interleaved requests come back on the
    right rows at the right slots — under both geometries."""
    for layout, page in (("paged", 4), ("contig", MAX_LEN)):
        kv = make_kv(page_size=page, layout=layout)
        kv.join("a")
        kv.join("b")
        for step in range(3):
            cache, lengths = kv.materialize(["a", "b"], 4)   # padded batch
            k = np.array(cache["k"])
            st_ = np.array(cache["state"])
            for row, base in ((0, 100.0), (1, 200.0)):
                assert int(lengths[row]) == step
                # history written in earlier steps is visible
                np.testing.assert_array_equal(
                    k[row, :step, 0], [base + s for s in range(step)])
                k[row, step] = base + step
                st_[row] = base + step
            kv.harvest(["a", "b"], {"k": jnp.asarray(k),
                                    "state": jnp.asarray(st_),
                                    "tick": cache["tick"]}, [1, 1])
        # per-row recurrent state tracked independently of the pages
        cache, _ = kv.materialize(["b", "a"], 2)    # reversed order
        assert np.asarray(cache["state"])[0, 0] == 202.0
        assert np.asarray(cache["state"])[1, 0] == 102.0


def test_join_live_and_overflow_raise():
    kv = make_kv(page_size=4, capacity=MAX_LEN)    # one request's worth
    kv.join("a")
    with pytest.raises(PageError):
        kv.join("a")                               # already live
    cache, _ = kv.materialize(["a"], 1)
    with pytest.raises(PageError):
        kv.harvest(["a"], cache, [MAX_LEN + 1])    # past max_len
    kv.harvest(["a"], cache, [MAX_LEN])            # exactly full is fine
    kv.join("b")
    cache, _ = kv.materialize(["b"], 1)
    with pytest.raises(PageError):                 # pool exhausted
        kv.harvest(["b"], cache, [1])


# -- phased executor determinism ------------------------------------------------

def _history_builder(spec):
    """Serve-contract handler whose next token is a deterministic function
    of the request's whole history: tokens+1 are written at their slots,
    and the logits peak at ``sum(written) mod VOCAB``.  Any cross-request
    page sharing, lost row state, or misplaced write changes the output
    stream."""

    def f(params, cache, tokens, pos, n_new):
        toks = tokens if tokens.ndim == 2 else tokens[:, None]
        c = toks.shape[1]
        k = cache["k"]
        slots = jnp.arange(k.shape[1])
        for t in range(c):
            at = (slots[None, :] == (pos + t)[:, None]) \
                & (t < n_new)[:, None]
            k = k.at[:, :, 0].set(
                jnp.where(at, (toks[:, t, None] + 1).astype(k.dtype),
                          k[:, :, 0]))
        total = k[:, :, 0].sum(axis=1)
        peak = jnp.mod(total, float(VOCAB))
        logits = -(jnp.arange(VOCAB)[None, :].astype(jnp.float32)
                   - peak[:, None]) ** 2
        return logits, {"k": k, "state": cache["state"] + 1.0,
                        "tick": cache["tick"]}

    return f


def _prompt_fn(req):
    return (np.arange(req.prompt_tokens, dtype=np.int32) * 3 + 1) % VOCAB


def _serve(reqs, layout="paged", bucket=2):
    rt = IridescentRuntime(async_compile=False)
    handler = rt.register("hist", _history_builder,
                          context_fn=phase_context_fn)
    kv = make_kv(page_size=4 if layout == "paged" else MAX_LEN,
                 layout=layout)
    executor = PhasedExecutor(handler, None, kv, prefill_chunk=2,
                              prompt_fn=_prompt_fn)
    engine = ServeEngine(handler, None,
                         ContinuousBatcher(bucket, scheme="single"),
                         FCFS(), executor=executor, queue=AdmissionQueue(),
                         metrics=ServeMetrics())
    for r in reqs:
        assert engine.submit(r)
    engine.run()
    rt.shutdown()
    return [list(r.payload) for r in reqs]


def test_interleaved_decode_matches_sequential():
    specs = [(5, 4), (3, 6), (7, 3)]               # (prompt, budget)
    together = _serve([Request(prompt_tokens=p, max_new_tokens=g)
                       for p, g in specs], bucket=2)
    alone = [_serve([Request(prompt_tokens=p, max_new_tokens=g)],
                    bucket=2)[0]
             for p, g in specs]
    assert together == alone
    for (p, g), out in zip(specs, together):
        assert len(out) == g
        assert all(0 <= t < VOCAB for t in out)


def test_paged_and_contig_geometries_decode_identically():
    specs = [(5, 4), (3, 6)]
    paged = _serve([Request(prompt_tokens=p, max_new_tokens=g)
                    for p, g in specs], layout="paged")
    contig = _serve([Request(prompt_tokens=p, max_new_tokens=g)
                     for p, g in specs], layout="contig")
    assert paged == contig


def test_executor_rejects_requests_that_cannot_fit():
    rt = IridescentRuntime(async_compile=False)
    handler = rt.register("hist", _history_builder,
                          context_fn=phase_context_fn)
    executor = PhasedExecutor(handler, None, make_kv(), prefill_chunk=2,
                              prompt_fn=_prompt_fn)
    with pytest.raises(ValueError):
        executor.ensure_joined(Request(prompt_tokens=MAX_LEN,
                                       max_new_tokens=1))
    rt.shutdown()


# -- tuple context keys: lossless persistence ----------------------------------

@settings(max_examples=20)
@given(st.tuples(st.sampled_from(["prefill", "decode"]),
                 st.integers(1, 128)))
def test_phase_context_key_roundtrip(key):
    enc = encode_context_key(key)
    assert decode_context_key(enc) == key
    assert encode_context_key(decode_context_key(enc)) == enc


@pytest.mark.parametrize("key", [
    ("prefill", 8),
    ("decode", 1),
    (("nested", 2), "x"),
    ("mixed", 3, True, None),
    (),
])
def test_tuple_context_key_roundtrip_cases(key):
    enc = encode_context_key(key)
    assert decode_context_key(enc) == key
    assert encode_context_key(decode_context_key(enc)) == enc


def _phase_toy_builder(spec):
    scale = spec.enum("scale", 1, (1, 2), guarded=False)

    def f(params, cache, tokens, pos, n_new):
        toks = tokens if tokens.ndim == 2 else tokens[:, None]
        return toks.sum(axis=1).astype(jnp.float32) * float(scale), cache

    return f


def _phase_calls(handler):
    cache = jnp.zeros((2, 4), jnp.float32)
    pos = jnp.zeros((2,), jnp.int32)
    handler(None, cache, jnp.zeros((2, 4), jnp.int32), pos,
            jnp.full((2,), 4, jnp.int32))              # ('prefill', 2)
    handler(None, cache, jnp.zeros((2,), jnp.int32), pos,
            jnp.ones((2,), jnp.int32))                 # ('decode', 2)


def test_per_phase_configs_warm_restart_zero_recompiles(tmp_path):
    """ISSUE acceptance: distinct per-(phase, bucket) configs persist
    through spec_state.json v2 tuple keys and come back on a warm restart
    without a single XLA recompile."""
    cache_dir = str(tmp_path / "state")
    state_path = os.path.join(cache_dir, "spec_state.json")
    variants = os.path.join(cache_dir, "variants")

    rt = IridescentRuntime(async_compile=False, variant_cache=variants)
    handler = rt.register("phase_toy", _phase_toy_builder,
                          context_fn=phase_context_fn)
    _phase_calls(handler)                              # materialize contexts
    handler.specialize({"scale": 2}, context=("prefill", 2), wait=True)
    handler.specialize({"scale": 1}, context=("decode", 2), wait=True)
    _phase_calls(handler)
    assert rt.compile_stats()["xla_compiles"] > 0
    save_spec_state(state_path, rt)
    rt.shutdown()

    rt2 = IridescentRuntime(async_compile=False, variant_cache=variants)
    handler2 = rt2.register("phase_toy", _phase_toy_builder,
                            context_fn=phase_context_fn)
    assert restore_spec_state(state_path, rt2, wait=True)
    _phase_calls(handler2)                             # traffic re-seeds
    assert handler2.active_config(
        context=("prefill", 2))["scale"] == 2
    assert handler2.active_config(
        context=("decode", 2))["scale"] == 1
    stats = rt2.compile_stats()
    assert stats["xla_compiles"] == 0, f"warm restart recompiled: {stats}"
    assert stats["cache_hits"] > 0
    rt2.shutdown()


# -- schedulers: job size includes remaining prefill ---------------------------

def _mk(prompt, budget, consumed=0, generated=0, arrival=0.0, deadline=None):
    r = Request(prompt_tokens=prompt, max_new_tokens=budget,
                deadline_s=deadline)
    r.arrival_t = arrival
    r.prompt_consumed = consumed
    r.generated = generated
    return r


def test_sjf_counts_remaining_prefill_as_work():
    long_prompt = _mk(2048, 4)                     # huge prefill ahead
    short_prompt = _mk(16, 32)
    key = ShortestJobFirst().key(now=0.0)
    assert key(short_prompt) < key(long_prompt)    # 48 < 2052
    assert long_prompt.remaining_work == 2052
    assert short_prompt.remaining_work == 48


def test_sjf_mid_stream_prefill_progress_reorders():
    half_done = _mk(100, 10, consumed=90, generated=0)    # 20 left
    fresh = _mk(40, 10)                                   # 50 left
    key = ShortestJobFirst().key(now=0.0)
    assert key(half_done) < key(fresh)


def test_legacy_executor_requests_fall_back_to_decode_budget():
    # A legacy (non-phased) executor never advances prompt_consumed; once
    # decoding, the prompt must not be double-counted as pending work.
    legacy = _mk(100, 10, consumed=0, generated=4)
    assert legacy.remaining_prefill == 0
    assert legacy.remaining_work == 6


def test_deadline_aware_breaks_ties_by_remaining_work():
    urgent_big = _mk(200, 8, arrival=0.0, deadline=1.0)
    urgent_small = _mk(10, 8, arrival=0.0, deadline=1.0)
    relaxed = _mk(1, 1, arrival=0.0, deadline=9.0)
    key = DeadlineAware().key(now=0.0)
    order = sorted([relaxed, urgent_big, urgent_small], key=key)
    assert order == [urgent_small, urgent_big, relaxed]
