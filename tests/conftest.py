import os
import sys

# Tests run single-device CPU (the 512-device flag lives ONLY in dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Offline fallback: when the real hypothesis package is absent, install the
# fixed-examples shim so property tests still collect and run (see
# tests/_hypothesis_shim.py for the degraded semantics).
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_shim

    _hypothesis_shim.install()
