import os
import sys

# Tests run single-device CPU (the 512-device flag lives ONLY in dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
