"""CompileService: priority ordering, dedup, stale cancellation, speculative
prefetch, and the activation-epoch guarantee (a superseded compile can never
overwrite a newer swap)."""
import threading
import time

import jax.numpy as jnp
import pytest

from repro.core import (CompileService, ExhaustiveSweep, Explorer,
                        IridescentRuntime, PRIORITY_ACTIVATE,
                        PRIORITY_SPECULATIVE)


class _Blocker:
    """Build callable that blocks until released, recording execution order."""

    def __init__(self):
        self.gate = threading.Event()
        self.order: list[str] = []

    def build(self, tag, block=False):
        def fn():
            if block:
                assert self.gate.wait(timeout=30)
            self.order.append(tag)
            return tag
        return fn


def test_priority_activation_before_speculative():
    svc = CompileService(workers=1)
    b = _Blocker()
    try:
        svc.submit("h", "k0", {}, b.build("k0", block=True))
        # wait until the worker is busy so the next two really queue
        deadline = time.time() + 10
        while svc.stats()["running"] != 1 and time.time() < deadline:
            time.sleep(0.01)
        svc.submit("h", "spec", {}, b.build("spec"),
                   priority=PRIORITY_SPECULATIVE, speculative=True)
        svc.submit("h", "act", {}, b.build("act"),
                   priority=PRIORITY_ACTIVATE)
        b.gate.set()
        assert svc.drain(timeout=30)
        # activation enqueued later but outranks the speculative build
        assert b.order == ["k0", "act", "spec"]
    finally:
        svc.shutdown()


def test_dedup_coalesces_inflight_requests():
    svc = CompileService(workers=1)
    b = _Blocker()
    try:
        r1 = svc.submit("h", "busy", {}, b.build("busy", block=True))
        r2 = svc.submit("h", "k", {}, b.build("k"))
        r3 = svc.submit("h", "k", {}, b.build("k-dup"))
        assert r2 is r3                    # coalesced onto one request
        b.gate.set()
        assert svc.drain(timeout=30)
        assert b.order.count("k") == 1 and "k-dup" not in b.order
        assert r1.status == "done"
    finally:
        svc.shutdown()


def test_activation_promotes_pending_speculative():
    svc = CompileService(workers=1)
    b = _Blocker()
    try:
        svc.submit("h", "busy", {}, b.build("busy", block=True))
        deadline = time.time() + 10
        while svc.stats()["running"] != 1 and time.time() < deadline:
            time.sleep(0.01)
        s1 = svc.submit("h", "s1", {}, b.build("s1"),
                        priority=PRIORITY_SPECULATIVE, speculative=True)
        s2 = svc.submit("h", "s2", {}, b.build("s2"),
                        priority=PRIORITY_SPECULATIVE, speculative=True)
        # the policy selects s2: its pending speculative build is promoted
        p = svc.submit("h", "s2", {}, b.build("s2-dup"),
                       priority=PRIORITY_ACTIVATE)
        assert p is s2 and s2.priority == PRIORITY_ACTIVATE
        assert not s2.speculative
        b.gate.set()
        assert svc.drain(timeout=30)
        assert b.order.index("s2") < b.order.index("s1")
        assert s1.status == "done"
    finally:
        svc.shutdown()


def test_cancel_stale_pending():
    svc = CompileService(workers=1)
    b = _Blocker()
    try:
        svc.submit("h", "busy", {}, b.build("busy", block=True))
        deadline = time.time() + 10
        while svc.stats()["running"] != 1 and time.time() < deadline:
            time.sleep(0.01)
        stale = svc.submit("h", "stale", {}, b.build("stale"),
                           priority=PRIORITY_ACTIVATE)
        n = svc.cancel_pending("h", keep_keys={"other"},
                               max_priority=PRIORITY_ACTIVATE)
        assert n == 1
        assert stale.status == "cancelled" and stale.future.cancelled()
        b.gate.set()
        assert svc.drain(timeout=30)
        assert "stale" not in b.order
        assert svc.stats()["cancelled"] == 1
    finally:
        svc.shutdown()


def test_sync_mode_runs_inline_and_skips_speculation():
    svc = CompileService(workers=0)
    b = _Blocker()
    r = svc.submit("h", "k", {}, b.build("k"))
    assert r.status == "done" and b.order == ["k"]
    s = svc.submit("h", "s", {}, b.build("s"),
                   priority=PRIORITY_SPECULATIVE, speculative=True)
    assert s.status == "cancelled" and "s" not in b.order
    svc.shutdown()


def test_failed_build_propagates_and_unblocks():
    svc = CompileService(workers=1)
    try:
        def boom():
            raise RuntimeError("no")
        r = svc.submit("h", "k", {}, boom)
        with pytest.raises(RuntimeError):
            r.future.result(timeout=30)
        assert r.status == "failed"
        assert svc.drain(timeout=10)
    finally:
        svc.shutdown()


# --- runtime-level integration -------------------------------------------------

def _wait_running_config(svc, label, value, timeout=10.0) -> bool:
    """Poll until a build whose config[label] == value is running."""
    deadline = time.time() + timeout
    while time.time() < deadline:
        with svc._lock:
            reqs = list(svc._inflight.values())
        if any(r.status == "running" and r.config.get(label) == value
               for r in reqs):
            return True
        time.sleep(0.01)
    return False


def _slow_builder_factory(slow_value, delay, built):
    def builder(spec):
        k = spec.enum("k", 1, (1, 2, 3))
        if k == slow_value:
            time.sleep(delay)
        built.append(k)
        return lambda x: x * k
    return builder


def test_explorer_speculative_prefetch_ordering():
    """The explorer's prefetch enqueues exactly the policy's upcoming
    candidates as speculative builds, and they execute in peek order."""
    rt = IridescentRuntime(async_compile=True, max_compile_workers=1)
    try:
        built: list = []
        gate = threading.Event()

        def builder(spec):
            # default 0 = the generic build; only candidate k=1 blocks
            k = spec.enum("k", 0, (1, 2, 3))
            if k == 1:
                assert gate.wait(timeout=30)
            built.append(k)
            return lambda x: x * k

        h = rt.register("m", builder)
        h(jnp.float32(2.0))
        policy = ExhaustiveSweep([{"k": 1}, {"k": 2}, {"k": 3}])
        upcoming = policy.peek(3)
        assert upcoming == [{"k": 1}, {"k": 2}, {"k": 3}]   # peek != consume
        Explorer(h, policy, dwell=50, wait_compiles=False, prefetch=2)
        # worker is stuck building k=1; k=2/k=3 must be queued speculatively
        assert _wait_running_config(rt.compile_service, "k", 1)
        with rt.compile_service._lock:
            pending = [r for r in rt.compile_service._inflight.values()
                       if r.status == "pending" and "k" in r.config]
        assert sorted(r.config["k"] for r in pending) == [2, 3]
        assert all(r.speculative for r in pending)
        gate.set()
        assert rt.compile_service.drain(timeout=60)
        assert [k for k in built if k not in (0, 1)] == [2, 3]   # peek order
    finally:
        gate.set()
        rt.shutdown()


def test_stale_activation_never_overwrites_newer_swap():
    """specialize(A) then specialize(B): if A's (slow) compile finishes
    after B's, A must not overwrite the active variant."""
    rt = IridescentRuntime(async_compile=True, max_compile_workers=2)
    try:
        built: list = []
        h = rt.register("m", _slow_builder_factory(2, 0.5, built))
        h(jnp.float32(2.0))
        h.specialize({"k": 2}, wait=False)      # slow build
        assert _wait_running_config(rt.compile_service, "k", 2)
        h.specialize({"k": 3}, wait=False)      # fast build, newer epoch
        assert rt.compile_service.drain(timeout=60)
        deadline = time.time() + 5
        while h.active_config().get("k") != 3 and time.time() < deadline:
            time.sleep(0.01)
        assert h.active_config().get("k") == 3
        assert 2 in built                        # A did finish compiling...
        assert float(h(jnp.float32(2.0))) == 6.0  # ...but B stays active
    finally:
        rt.shutdown()


def test_despecialize_honors_wait_and_cancels_pending():
    rt = IridescentRuntime(async_compile=True, max_compile_workers=1)
    try:
        built: list = []
        h = rt.register("m", _slow_builder_factory(2, 0.5, built))
        h(jnp.float32(2.0))
        h.specialize({"k": 2}, wait=False)      # starts the slow build
        h.specialize({"k": 3}, wait=False)      # queues behind it
        h.despecialize(wait=True)
        # wait=True: on return no build work remains for this handler,
        # pending requests were cancelled, and the in-flight compile that
        # completed during the drain did not overwrite the generic swap.
        stats = rt.compile_service.stats()
        assert stats["pending"] == 0 and stats["running"] == 0
        assert h.active_config() == {}
        assert 3 not in built                    # cancelled before building
        assert float(h(jnp.float32(2.0))) == 2.0
    finally:
        rt.shutdown()
