"""Flight recorder: ring semantics, concurrency, trace export, emit sites,
and the hot-path guarantee (ISSUE 9)."""
import json
import threading

import pytest

from repro.core import telemetry
from repro.core.telemetry import EventBus, export_chrome_trace


@pytest.fixture(autouse=True)
def _no_process_bus():
    """Each test starts with telemetry disabled; restore whatever was
    installed afterwards."""
    prev = telemetry.install(None)
    yield
    telemetry.install(prev)


# -- ring semantics -----------------------------------------------------------

def test_ring_overflow_drops_oldest_and_counts():
    b = EventBus(capacity=8)
    for i in range(20):
        b.emit("t.tick", i=i)
    assert b.emitted() == 20
    assert b.dropped() == 12
    evs = b.events()
    assert len(evs) == 8
    # the retained tail is the *newest* 8, oldest first
    assert [e["i"] for e in evs] == list(range(12, 20))
    assert b.stats()["dropped_events"] == 12


def test_ring_below_capacity_retains_everything():
    b = EventBus(capacity=64)
    for i in range(10):
        b.emit("t.tick", i=i)
    assert b.dropped() == 0
    assert [e["i"] for e in b.events()] == list(range(10))


def test_capacity_validated():
    with pytest.raises(ValueError):
        EventBus(capacity=0)


def test_concurrent_emit_loses_nothing_below_capacity():
    b = EventBus(capacity=65536)
    threads = []
    per_thread = 500

    def worker(tid):
        for i in range(per_thread):
            b.emit("t.thread", tid=tid, i=i)

    for t in range(8):
        threads.append(threading.Thread(target=worker, args=(t,)))
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert b.emitted() == 8 * per_thread
    assert b.dropped() == 0
    evs = b.events()
    assert len(evs) == 8 * per_thread
    # every (tid, i) pair survived exactly once
    seen = {(e["tid"], e["i"]) for e in evs}
    assert len(seen) == 8 * per_thread


def test_span_measures_and_carries_mutated_payload():
    b = EventBus()
    with b.span("t.work", track=("ctx", 1)) as p:
        p["status"] = "done"
    (ev,) = b.events()
    assert ev["kind"] == "span"
    assert ev["dur"] >= 0
    assert ev["status"] == "done"
    assert ev["track"] == repr(("ctx", 1))


def test_sink_receives_events_and_broken_sink_never_blocks():
    b = EventBus()
    got = []
    b.add_sink(got.append)
    b.add_sink(lambda ev: 1 / 0)          # must be swallowed
    b.emit("t.x")
    assert len(got) == 1
    b.remove_sink(got.append)
    b.emit("t.y")
    assert len(got) == 1


def test_absorb_tags_replica_and_skips_junk():
    b = EventBus()
    n = b.absorb([{"name": "t.x", "ts": 1.0}, "junk", {"no_name": 1}],
                 replica="3")
    assert n == 1
    (ev,) = b.events()
    assert ev["replica"] == "3"


# -- chrome trace export ------------------------------------------------------

def test_chrome_trace_round_trips_and_has_required_fields(tmp_path):
    b = EventBus()
    b.emit("dispatch.activate", track=("decode", 8), config="{'a': 1}")
    b.emit("compile.build", "span", dur=1234.5, handler="h", status="done")
    b.emit("serve.queue_depth", "counter", depth=3, label="x")
    b.absorb([{"name": "t.remote", "kind": "instant", "ts": 9.0}],
             replica="1")
    path = tmp_path / "trace.json"
    doc = export_chrome_trace(b.events(), str(path))
    loaded = json.loads(path.read_text())
    assert loaded == json.loads(json.dumps(doc))
    evs = loaded["traceEvents"]
    for ev in evs:
        assert {"ph", "ts", "pid", "tid", "name"} <= set(ev)
    by_ph = {e["name"]: e["ph"] for e in evs if e["ph"] not in ("M",)}
    assert by_ph["compile.build"] == "X"
    assert by_ph["dispatch.activate"] == "i"
    assert by_ph["serve.queue_depth"] == "C"
    # counters keep only numeric args
    cnt = next(e for e in evs if e["name"] == "serve.queue_depth")
    assert cnt["args"] == {"depth": 3}
    # the remote replica got its own pid
    pids = {e["pid"] for e in evs if e["ph"] != "M"}
    assert len(pids) == 2


# -- snapshot writer + status renderer ----------------------------------------

def test_snapshot_writer_atomic_and_final_write(tmp_path):
    path = tmp_path / "snap.json"
    calls = []

    def provider():
        calls.append(1)
        return {"mode": "single", "n": len(calls)}

    w = telemetry.SnapshotWriter(str(path), provider, interval_s=0.05)
    try:
        import time
        deadline = time.time() + 5.0
        while not path.exists() and time.time() < deadline:
            time.sleep(0.01)
    finally:
        w.close()
    doc = json.loads(path.read_text())
    assert doc["mode"] == "single"
    assert "written_at" in doc
    assert not list(tmp_path.glob("*.tmp.*"))     # no torn temp left behind


def test_snapshot_writer_survives_broken_provider(tmp_path):
    path = tmp_path / "snap.json"
    w = telemetry.SnapshotWriter(str(path), lambda: 1 / 0, interval_s=0.05)
    w.close()                              # must not raise


def test_status_render_single_and_fleet():
    from repro.launch.status import render

    doc = {"mode": "single", "handler": "serve_step", "written_at": 0.0,
           "contexts": {"('decode', 8)": {
               "phase": "exploit", "active": {"tile": 8}, "pending": None,
               "best_metric": 12.5, "calls": 100, "explorations": 1,
               "tput_window": {"rate": 42.0}}},
           "safety": {"promotions": 1, "rollbacks": 1,
                      "shadow_rejections": 0, "canary_rejections": 0,
                      "quarantined": 1,
                      "contexts": {"('decode', 8)": {
                          "stage": "live", "quarantined": [{"tile": 64}]}}},
           "compile": {"queue_depth": 0, "in_flight": 0,
                       "cache_hit_rate": 1.0, "build_p50_s": 0.001},
           "bus": {"emitted": 10, "dropped_events": 0, "retained": 10}}
    out = render(doc, now=2.0)
    assert "('decode', 8)" in out and "exploit" in out and "live" in out
    assert "tile=8" in out and "42.0" in out
    assert "rollbacks=1" in out
    fleet = render({"mode": "fleet", "written_at": 0.0,
                    "replicas": {"0": {"depth": 3}, "1": {"depth": 1}},
                    "router": {"policy": "jsq"}}, now=1.0)
    assert "replica" in fleet and "jsq" in fleet


# -- process-wide bus lifecycle -----------------------------------------------

def test_enable_disable_install():
    assert telemetry.bus() is None
    b = telemetry.enable(capacity=16)
    assert telemetry.bus() is b
    assert telemetry.enable() is b        # idempotent
    telemetry.disable()
    assert telemetry.bus() is None


# -- emit sites through the runtime -------------------------------------------

def test_runtime_emits_lifecycle_and_compile_events():
    from repro.core import IridescentRuntime

    b = telemetry.enable(capacity=4096)
    rt = IridescentRuntime(async_compile=False)
    try:
        def builder(spec):
            k = spec.enum("k", 1, (1, 2))
            return lambda x: x * k

        h = rt.register("tele_h", builder)
        import jax.numpy as jnp
        x = jnp.float32(2.0)
        h(x)
        h.specialize({"k": 2}, wait=True)
        h(x)
        names = {e["name"] for e in b.events()}
        assert "dispatch.activate" in names
        assert "compile.queued" in names
        assert "compile.build" in names
        build = next(e for e in b.events() if e["name"] == "compile.build")
        assert build["kind"] == "span"
        assert build["status"] == "done"
        assert build["dur"] >= 0
        st = rt.compile_stats()
        assert st["queue_depth"] == 0
        assert st["in_flight"] == 0
        assert st["build_p50_s"] is not None
    finally:
        rt.shutdown()


def test_compile_stats_shape_without_bus():
    from repro.core import IridescentRuntime

    rt = IridescentRuntime(async_compile=False)
    try:
        h = rt.register("tele_h2", lambda spec: (lambda x: x + 1))
        import jax.numpy as jnp
        h(jnp.float32(1.0))
        st = rt.compile_stats()
        for k in ("queue_depth", "in_flight", "cache_hit_rate",
                  "build_p50_s", "compile_p50_s"):
            assert k in st
    finally:
        rt.shutdown()


# -- HostRecorder saturation (ISSUE 9 satellite) -------------------------------

def test_host_recorder_saturation_is_counted_and_reported():
    from repro.core.instrumentation import HostRecorder

    b = telemetry.enable()
    rec = HostRecorder("vals", lambda a, k: int(a[0]), rate=1.0, maxlen=4)
    for v in range(4):
        rec.maybe_record((v,), {})
    assert rec.evicted == 0
    # new keys past maxlen are dropped — but now visibly
    for v in range(4, 10):
        rec.maybe_record((v,), {})
    rec.maybe_record((0,), {})            # existing key still counts
    assert rec.evicted == 6
    assert rec.samples == 11
    s = rec.summary()
    assert s["saturated"] is True and s["evicted"] == 6
    assert rec.counter[0] == 2
    sat = [e for e in b.events() if e["name"] == "instrument.saturated"]
    assert len(sat) == 1                  # warned once, not per sample
    assert sat[0]["label"] == "vals" and sat[0]["maxlen"] == 4


def test_host_recorder_unsaturated_summary_flags_clean():
    from repro.core.instrumentation import HostRecorder

    rec = HostRecorder("vals", lambda a, k: int(a[0]), rate=1.0, maxlen=8)
    rec.maybe_record((1,), {})
    s = rec.summary()
    assert s["saturated"] is False and s["evicted"] == 0


# -- hot path: fig11 dispatch_telemetry_off within noise of dispatch_fast ------

def test_dispatch_fast_path_unchanged_by_telemetry():
    from benchmarks.common import measure_dispatch_overhead

    d = measure_dispatch_overhead(iters=100)
    fast, off, on = (d["trampoline_fast"], d["trampoline_telemetry_off"],
                     d["trampoline_telemetry_on"])
    # The fast path is uninstrumented, so both readings should track
    # trampoline_fast.  Shared CI hosts jitter µs-scale medians hard;
    # the bound is deliberately generous (3x + 30µs slack) — the real
    # regression this guards against is an emit landing on the fast path,
    # which costs far more than 3x on this nanobenchmark.
    assert off < fast * 3 + 30.0
    assert on < fast * 3 + 30.0
