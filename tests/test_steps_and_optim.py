"""Step-builder invariants + optimizer behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.specializer import discover_space, specialize_builder
from repro.models import transformer as model
from repro.optim import OptConfig, apply_updates, cosine_lr, init_opt_state
from repro.training import cross_entropy, make_train_builder

CFG = configs.get_reduced("yi-6b").replace(compute_dtype="float32")
OPT = OptConfig(lr=1e-2, warmup_steps=1, total_steps=100)


def _state_and_batch(cfg=CFG, b=4, s=16):
    params = model.init_params(jax.random.PRNGKey(0), cfg)
    state = {"params": params, "opt": init_opt_state(params, OPT)}
    toks = jax.random.randint(jax.random.PRNGKey(7), (b, s + 1), 0,
                              cfg.vocab_size)
    return state, {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def test_spec_space_discovered():
    space = discover_space(make_train_builder(CFG, OPT, kernel_impl="xla"))
    labels = set(space.labels())
    assert {"remat", "microbatch", "block_q", "block_kv", "logits_layout",
            "sharding_profile", "logits_dtype"} <= labels


def test_microbatch_equivalence():
    """Grad accumulation (microbatch spec point) must not change the math."""
    state, batch = _state_and_batch()
    builder = make_train_builder(CFG, OPT, kernel_impl="xla")
    outs = {}
    for m in (1, 2, 4):
        step = jax.jit(specialize_builder(builder, {"microbatch": m}).fn)
        s2, metrics = step(jax.tree_util.tree_map(jnp.copy, state), batch)
        outs[m] = (float(metrics["loss"]),
                   np.asarray(jax.tree_util.tree_leaves(s2["params"])[0]))
    for m in (2, 4):
        assert abs(outs[m][0] - outs[1][0]) < 1e-4
        np.testing.assert_allclose(outs[m][1], outs[1][1], rtol=2e-4,
                                   atol=2e-4)


def test_remat_equivalence():
    """Remat policies change memory, never the result."""
    state, batch = _state_and_batch()
    builder = make_train_builder(CFG, OPT, kernel_impl="xla")
    ref = None
    for remat in ("none", "dots", "full"):
        step = jax.jit(specialize_builder(builder, {"remat": remat}).fn)
        _, metrics = step(jax.tree_util.tree_map(jnp.copy, state), batch)
        if ref is None:
            ref = float(metrics["loss"])
        else:
            assert abs(float(metrics["loss"]) - ref) < 1e-4


def test_logits_layout_equivalence():
    state, batch = _state_and_batch()
    builder = make_train_builder(CFG, OPT, kernel_impl="xla")
    losses = []
    for layout in ("sharded", "gathered"):
        step = jax.jit(specialize_builder(
            builder, {"logits_layout": layout}).fn)
        _, m = step(jax.tree_util.tree_map(jnp.copy, state), batch)
        losses.append(float(m["loss"]))
    assert abs(losses[0] - losses[1]) < 1e-5


def test_cross_entropy_masking():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.array([[1, 2, -1, -1]])
    loss = cross_entropy(logits, labels)
    np.testing.assert_allclose(float(loss), np.log(8), rtol=1e-5)


def test_cosine_schedule_monotone_warmup():
    lrs = [float(cosine_lr(OPT, jnp.float32(s))) for s in range(0, 5)]
    assert lrs[0] <= lrs[1]
    assert abs(lrs[1] - OPT.lr) < 1e-6   # warmup_steps=1
    late = float(cosine_lr(OPT, jnp.float32(OPT.total_steps)))
    assert late < 1e-4


def test_clip_norm_bounds_update():
    cfg = OptConfig(lr=1.0, warmup_steps=0, total_steps=10, clip_norm=1e-3,
                    weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    st = init_opt_state(params, cfg)
    g = {"w": jnp.full(4, 1e6)}
    p2, _ = apply_updates(params, g, st, cfg)
    # clipped: first Adam step is bounded by lr regardless of raw grad
    assert float(jnp.abs(p2["w"]).max()) <= 1.1 * cfg.lr


def test_int8_ef_error_feedback_accumulates():
    cfg = OptConfig(compress="int8_ef")
    params = {"w": jnp.zeros(3)}
    st = init_opt_state(params, cfg)
    assert "ef" in st
    g = {"w": jnp.array([1e-9, 1.0, -1.0])}   # tiny grad lost to quant
    _, st2 = apply_updates(params, g, st, cfg)
    assert float(jnp.abs(st2["ef"]["w"][0])) > 0  # error retained for later


def test_chunked_ce_equals_full():
    """loss_chunk spec point: identical loss & params (never materializes
    the (B,S,V) fp32 logits)."""
    state, batch = _state_and_batch()
    builder = make_train_builder(CFG, OPT, kernel_impl="xla")
    outs = {}
    for lc in (0, 16):
        step = jax.jit(specialize_builder(
            builder, {"loss_chunk": lc} if lc else {}).fn)
        s2, m = step(jax.tree_util.tree_map(jnp.copy, state), batch)
        outs[lc] = (float(m["loss"]),
                    np.asarray(jax.tree_util.tree_leaves(s2["params"])[0]))
    assert abs(outs[0][0] - outs[16][0]) < 1e-5
    np.testing.assert_allclose(outs[0][1], outs[16][1], rtol=2e-4, atol=2e-4)
