"""Chunked linear recurrence vs per-step oracle (property-based)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models.chunk_scan import (chunked_linear_attention,
                                     naive_linear_attention,
                                     step_linear_attention)

RS = np.random.RandomState(1)


@settings(max_examples=15, deadline=None)
@given(
    st.sampled_from([16, 32, 64, 128]),       # T
    st.sampled_from([4, 8, 16]),              # chunk
    st.sampled_from([4, 8]),                  # dk
    st.sampled_from([4, 12]),                 # dv
    st.booleans(),                            # inclusive
    st.booleans(),                            # bonus
    st.booleans(),                            # scalar decay
)
def test_property_chunked_equals_naive(t, c, dk, dv, inclusive, use_bonus,
                                       scalar_decay):
    if c > t:
        c = t
    if inclusive:
        use_bonus = False
    q = jnp.asarray(RS.randn(t, dk).astype(np.float32))
    k = jnp.asarray(RS.randn(t, dk).astype(np.float32))
    v = jnp.asarray(RS.randn(t, dv).astype(np.float32))
    lw_shape = (t, 1) if scalar_decay else (t, dk)
    lw = jnp.asarray(-np.clip(RS.rand(*lw_shape), 1e-4, 1.0)
                     .astype(np.float32))
    bonus = jnp.asarray(RS.randn(dk).astype(np.float32)) if use_bonus else None
    s0 = jnp.asarray(RS.randn(dk, dv).astype(np.float32) * 0.1)

    o1, f1 = chunked_linear_attention(q, k, v, lw, bonus=bonus,
                                      inclusive=inclusive, chunk=c,
                                      init_state=s0, return_state=True)
    o2, f2 = naive_linear_attention(q, k, v, lw, bonus=bonus,
                                    inclusive=inclusive, init_state=s0,
                                    return_state=True)
    np.testing.assert_allclose(o1, o2, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(f1, f2, rtol=5e-4, atol=5e-4)


def test_step_chain_matches_naive():
    t, dk, dv = 12, 6, 5
    q = jnp.asarray(RS.randn(t, dk).astype(np.float32))
    k = jnp.asarray(RS.randn(t, dk).astype(np.float32))
    v = jnp.asarray(RS.randn(t, dv).astype(np.float32))
    lw = jnp.asarray(-np.clip(RS.rand(t, dk), 1e-4, 1.0).astype(np.float32))
    u = jnp.asarray(RS.randn(dk).astype(np.float32))
    S = jnp.zeros((dk, dv), jnp.float32)
    outs = []
    for i in range(t):
        o, S = step_linear_attention(q[i], k[i], v[i], lw[i], S, bonus=u)
        outs.append(o)
    ref = naive_linear_attention(q, k, v, lw, bonus=u)
    np.testing.assert_allclose(jnp.stack(outs), ref, rtol=1e-5, atol=1e-5)


def test_state_chaining_across_calls():
    """Splitting a sequence across two chunked calls == one call."""
    t, dk, dv, c = 64, 8, 8, 8
    q = jnp.asarray(RS.randn(t, dk).astype(np.float32))
    k = jnp.asarray(RS.randn(t, dk).astype(np.float32))
    v = jnp.asarray(RS.randn(t, dv).astype(np.float32))
    lw = jnp.asarray(-np.clip(RS.rand(t, dk), 1e-4, 1.0).astype(np.float32))
    o_full = chunked_linear_attention(q, k, v, lw, chunk=c, inclusive=True)
    h = t // 2
    o1, s = chunked_linear_attention(q[:h], k[:h], v[:h], lw[:h], chunk=c,
                                     inclusive=True, return_state=True)
    o2 = chunked_linear_attention(q[h:], k[h:], v[h:], lw[h:], chunk=c,
                                  inclusive=True, init_state=s)
    np.testing.assert_allclose(jnp.concatenate([o1, o2]), o_full,
                               rtol=5e-4, atol=5e-4)
