"""Workload-contextual specialization: per-context dispatch snapshots.

One handler + a context_fn: each workload class (e.g. batch-shape) keeps
its own active variant, stats, guard-miss counters, and argument specs;
the legacy context-less API keeps targeting the default context.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_spec_state, save_spec_state
from repro.core import (DEFAULT_CONTEXT, IridescentRuntime,
                        encode_context_key, guards)


def _mm_builder(spec):
    B = spec.enum("B", 8, (4, 8, 16))

    def matmul(L, R):
        return (L @ R) * 1.0

    return matmul


def _batch_ctx(args, kwargs):
    return int(args[0].shape[0])


def make_rt(**kw):
    return IridescentRuntime(async_compile=False, **kw)


def test_contexts_materialize_on_dispatch():
    rt = make_rt()
    h = rt.register("m", _mm_builder, context_fn=_batch_ctx)
    assert h.contexts() == [DEFAULT_CONTEXT]
    h(jnp.ones((4, 4)), jnp.eye(4))
    h(jnp.ones((8, 8)), jnp.eye(8))
    assert set(h.contexts()) == {DEFAULT_CONTEXT, 4, 8}
    rt.shutdown()


def test_per_context_active_variants():
    """Each batch-shape class dispatches to its own active variant."""
    rt = make_rt()
    h = rt.register("m", _mm_builder, context_fn=_batch_ctx)
    h(jnp.ones((4, 4)), jnp.eye(4))
    h(jnp.ones((8, 8)), jnp.eye(8))
    h.specialize({"B": 4}, context=4, wait=True)
    h.specialize({"B": 16}, context=8, wait=True)
    assert h.active_config(context=4) == {"B": 4}
    assert h.active_config(context=8) == {"B": 16}
    # dispatch stays correct in both contexts after the split
    np.testing.assert_allclose(h(jnp.ones((4, 4)), jnp.eye(4)),
                               np.ones((4, 4)))
    np.testing.assert_allclose(h(jnp.ones((8, 8)), jnp.eye(8)),
                               np.ones((8, 8)))
    rt.shutdown()


def test_specializing_one_context_leaves_others_alone():
    rt = make_rt()
    h = rt.register("m", _mm_builder, context_fn=_batch_ctx)
    h(jnp.ones((4, 4)), jnp.eye(4))
    h(jnp.ones((8, 8)), jnp.eye(8))
    h.specialize({"B": 16}, context=4, wait=True)
    assert h.active_config(context=4) == {"B": 16}
    assert h.active_config(context=8) == {}          # still generic
    rt.shutdown()


def test_default_context_backcompat():
    """The legacy context-less API (rt.specialize, handler.specialize)
    targets the default context and behaves exactly as before."""
    rt = make_rt()
    h = rt.register("m", _mm_builder)                # no context_fn
    h(jnp.ones((4, 4)), jnp.eye(4))
    rt.specialize({"B": 4}, wait=True)
    assert h.active_config() == {"B": 4}
    assert h.contexts() == [DEFAULT_CONTEXT]
    assert h.active_config(context=DEFAULT_CONTEXT) == {"B": 4}
    rt.shutdown()


def test_per_context_guard_miss_counters():
    def b(spec):
        N = spec.generic("N", None, guard=guards.shape_equals(0, 0))
        return lambda L, R: (L @ R) * 1.0

    rt = make_rt()
    h = rt.register("m", b, context_fn=_batch_ctx)
    h(jnp.ones((4, 4)), jnp.eye(4))
    h(jnp.ones((8, 8)), jnp.eye(8))
    # context 4 gets an assumption that never holds there
    h.specialize({"N": 999}, context=4, wait=True)
    for _ in range(3):
        out = h(jnp.ones((4, 4)), jnp.eye(4))        # miss -> generic
        np.testing.assert_allclose(out, np.ones((4, 4)))
        h(jnp.ones((8, 8)), jnp.eye(8))              # other context: clean
    assert h.context(4).guard_misses == 3
    assert h.context(8).guard_misses == 0
    assert h.guard_misses == 3                        # handler aggregates
    rt.shutdown()


def test_per_context_arg_specs_no_cross_demotion():
    """Contexts with different shapes AOT-compile independently: calls in
    one context never poison (demote) another context's AOT path."""
    rt = make_rt()
    h = rt.register("m", _mm_builder, context_fn=_batch_ctx)
    for _ in range(5):
        h(jnp.ones((4, 4)), jnp.eye(4))
        h(jnp.ones((8, 8)), jnp.eye(8))
    for key in (4, 8):
        ctx = h._ctx_map[key]
        variant = ctx.variants[ctx.active_key]
        assert variant.compiled is not None, f"context {key} lost its AOT"
        assert variant._aot_failures == 0
    rt.shutdown()


def test_per_context_stats_and_counters():
    rt = make_rt()
    h = rt.register("m", _mm_builder, context_fn=_batch_ctx)
    for _ in range(3):
        h(jnp.ones((4, 4)), jnp.eye(4))
    h(jnp.ones((8, 8)), jnp.eye(8))
    stats = h.stats()
    per_ctx = stats["contexts"]
    assert per_ctx[encode_context_key(4)]["calls"] == 3
    assert per_ctx[encode_context_key(8)]["calls"] == 1
    # handler-level tput aggregates across contexts
    assert h.tput.total() == 4
    assert h.context(4).calls() == 3
    rt.shutdown()


def test_despecialize_single_context_and_all():
    rt = make_rt()
    h = rt.register("m", _mm_builder, context_fn=_batch_ctx)
    h(jnp.ones((4, 4)), jnp.eye(4))
    h(jnp.ones((8, 8)), jnp.eye(8))
    h.specialize({"B": 4}, context=4, wait=True)
    h.specialize({"B": 16}, context=8, wait=True)
    h.despecialize(context=4)
    assert h.active_config(context=4) == {}
    assert h.active_config(context=8) == {"B": 16}    # untouched
    h.despecialize()                                  # all contexts
    assert h.active_config(context=8) == {}
    rt.shutdown()


def test_unhashable_context_key_rejected():
    rt = make_rt()
    h = rt.register("m", _mm_builder,
                    context_fn=lambda a, k: list(a[0].shape))
    with pytest.raises(TypeError, match="hashable"):
        h(jnp.ones((4, 4)), jnp.eye(4))
    rt.shutdown()


def test_context_view_surface():
    rt = make_rt()
    h = rt.register("m", _mm_builder, context_fn=_batch_ctx)
    h(jnp.ones((4, 4)), jnp.eye(4))
    view = h.context(4)
    view.specialize({"B": 16}, wait=True)
    assert view.active_config() == {"B": 16}
    assert view.has_variant({"B": 16})
    assert not view.has_variant({"B": 4})
    assert view.calls() == 1
    view.despecialize()
    assert view.active_config() == {}
    rt.shutdown()


# --- persistence: per-context spec_state.json (v2) + legacy loader ------------

def test_spec_state_roundtrip_per_context(tmp_path):
    path = str(tmp_path / "spec_state.json")
    rt = make_rt()
    h = rt.register("m", _mm_builder, context_fn=_batch_ctx)
    h(jnp.ones((4, 4)), jnp.eye(4))
    h(jnp.ones((8, 8)), jnp.eye(8))
    h.specialize({"B": 4}, context=4, wait=True)
    h.specialize({"B": 16}, context=8, wait=True)
    save_spec_state(path, rt)
    rt.shutdown()

    with open(path) as f:
        raw = json.load(f)
    assert raw["version"] == 3
    assert encode_context_key(4) in raw["handlers"]["m"]["contexts"]

    # fresh process: restore seeds the non-default contexts; the moment
    # traffic materializes each context, its tuned config is re-applied.
    rt2 = make_rt()
    h2 = rt2.register("m", _mm_builder, context_fn=_batch_ctx)
    assert restore_spec_state(path, rt2, wait=True)
    assert h2.seeded_config(4) == {"B": 4}
    h2(jnp.ones((4, 4)), jnp.eye(4))                  # materializes ctx 4
    h2(jnp.ones((8, 8)), jnp.eye(8))
    rt2.compile_service.drain(timeout=30)
    assert h2.active_config(context=4) == {"B": 4}
    assert h2.active_config(context=8) == {"B": 16}
    rt2.shutdown()


def test_spec_state_legacy_flat_format_loads(tmp_path):
    """The old flat {handler: config} format still loads — it targets the
    default context."""
    path = str(tmp_path / "spec_state.json")
    with open(path, "w") as f:
        json.dump({"m": {"B": 4}}, f)
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    h(jnp.ones((4, 4)), jnp.eye(4))
    assert restore_spec_state(path, rt, wait=True)
    assert h.active_config() == {"B": 4}
    rt.shutdown()


def test_spec_state_stale_config_degrades_to_generic(tmp_path):
    path = str(tmp_path / "spec_state.json")
    with open(path, "w") as f:
        json.dump({"version": 2, "handlers": {
            "m": {"contexts": {encode_context_key(DEFAULT_CONTEXT):
                               {"no_such_point": 1}}}}}, f)
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    restore_spec_state(path, rt, wait=True)           # must not raise
    out = h(jnp.ones((4, 4)), jnp.eye(4))
    np.testing.assert_allclose(out, np.ones((4, 4)))
    assert h.active_config() == {}
    rt.shutdown()


def test_spec_state_malformed_v2_degrades_to_generic(tmp_path):
    """A truncated / hand-edited v2 file must never crash startup."""
    path = str(tmp_path / "spec_state.json")
    with open(path, "w") as f:
        json.dump({"version": 2, "handlers": {
            "m": {"contexts": {encode_context_key(DEFAULT_CONTEXT): None}},
            "n": {"contexts": "garbage"}}}, f)
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    restore_spec_state(path, rt, wait=True)           # must not raise
    out = h(jnp.ones((4, 4)), jnp.eye(4))
    np.testing.assert_allclose(out, np.ones((4, 4)))
    assert h.active_config() == {}
    rt.shutdown()


def test_save_preserves_unmaterialized_seeded_contexts(tmp_path):
    """Run 2 sees traffic for only one of run 1's tuned contexts; saving
    must not drop the other context's paid-for config."""
    path = str(tmp_path / "spec_state.json")
    rt = make_rt()
    h = rt.register("m", _mm_builder, context_fn=_batch_ctx)
    h(jnp.ones((4, 4)), jnp.eye(4))
    h(jnp.ones((8, 8)), jnp.eye(8))
    h.specialize({"B": 4}, context=4, wait=True)
    h.specialize({"B": 16}, context=8, wait=True)
    save_spec_state(path, rt)
    rt.shutdown()

    rt2 = make_rt()
    h2 = rt2.register("m", _mm_builder, context_fn=_batch_ctx)
    restore_spec_state(path, rt2, wait=True)
    h2(jnp.ones((4, 4)), jnp.eye(4))                  # only ctx 4 traffic
    rt2.compile_service.drain(timeout=30)
    save_spec_state(path, rt2)                        # must keep ctx 8
    rt2.shutdown()

    rt3 = make_rt()
    h3 = rt3.register("m", _mm_builder, context_fn=_batch_ctx)
    restore_spec_state(path, rt3, wait=True)
    h3(jnp.ones((8, 8)), jnp.eye(8))
    rt3.compile_service.drain(timeout=30)
    assert h3.active_config(context=8) == {"B": 16}
    rt3.shutdown()


def test_compile_cost_estimates_surfaced_per_config():
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    h(jnp.ones((4, 4)), jnp.eye(4))
    h.specialize({"B": 4}, wait=True)
    svc = rt.compile_service
    est = svc.estimate_compile_s("m", config={"B": 4})
    assert est is not None and est > 0
    per_cfg = svc.cost_estimates("m")
    assert any(v["mean_compile_s"] for v in per_cfg.values())
    rt.shutdown()


def test_seeded_config_applied_when_context_appears_late():
    rt = make_rt()
    h = rt.register("m", _mm_builder, context_fn=_batch_ctx)
    h.seed_spec_state(encode_context_key(4), {"B": 16})
    h(jnp.ones((8, 8)), jnp.eye(8))                   # a different context
    assert h.active_config(context=8) == {}
    h(jnp.ones((4, 4)), jnp.eye(4))                   # ctx 4 materializes
    rt.compile_service.drain(timeout=30)
    assert h.active_config(context=4) == {"B": 16}
    rt.shutdown()


def test_property_context_routing_stays_correct():
    """For any mix of shapes and per-context configs, every call's output
    equals the generic function's (the paper's correctness guarantee,
    per context)."""
    rt = make_rt()
    h = rt.register("m", _mm_builder, context_fn=_batch_ctx)
    shapes = [2, 4, 6, 8]
    for n in shapes:
        x = jnp.arange(n * n, dtype=jnp.float32).reshape(n, n)
        np.testing.assert_allclose(h(x, jnp.eye(n)), np.asarray(x),
                                   rtol=1e-6)
    for n, b in zip(shapes, (4, 8, 16, 4)):
        h.specialize({"B": b}, context=n, wait=True)
    for n in shapes:
        x = jnp.arange(n * n, dtype=jnp.float32).reshape(n, n)
        np.testing.assert_allclose(h(x, jnp.eye(n)), np.asarray(x),
                                   rtol=1e-6)
    rt.shutdown()


# -- per-context instrumentation (ROADMAP: enable_instrumentation used to
# -- target the default context only) ------------------------------------------

def test_enable_instrumentation_per_context():
    """Instrumenting one workload class samples only that class's calls;
    every other context keeps its uninstrumented lock-free fast path."""
    rt = make_rt()
    h = rt.register("m", _mm_builder, context_fn=_batch_ctx)
    h(jnp.ones((4, 4)), jnp.eye(4))
    h(jnp.ones((8, 8)), jnp.eye(8))
    h.context(4).enable_instrumentation(
        rate=1.0, collectors={"rows": lambda a, k: int(a[0].shape[0])})
    for _ in range(3):
        h(jnp.ones((4, 4)), jnp.eye(4))
        h(jnp.ones((8, 8)), jnp.eye(8))
    observed = h.spec_space().observed["rows"]
    # only context 4's calls were sampled
    assert observed["samples"] == 3
    assert dict(observed["top"]) == {4: 3}
    # context 4 is on the instrumented slow path, context 8 untouched
    assert h._ctx_map[4].snapshot.variant.specialized.instrumented
    assert h._ctx_map[8].snapshot.fast is not None
    assert not h._ctx_map[8].snapshot.variant.specialized.instrumented
    rt.shutdown()


def test_disable_instrumentation_per_context_restores_fast_path():
    rt = make_rt()
    h = rt.register("m", _mm_builder, context_fn=_batch_ctx)
    h(jnp.ones((4, 4)), jnp.eye(4))
    view = h.context(4)
    view.enable_instrumentation(rate=1.0)
    assert h._ctx_map[4].snapshot.fast is None        # sampling forces slow
    view.disable_instrumentation()
    h(jnp.ones((4, 4)), jnp.eye(4))
    snap = h._ctx_map[4].snapshot
    assert not snap.variant.specialized.instrumented
    assert snap.fast is not None                      # fast path restored
    rt.shutdown()


def test_contextless_instrumentation_unchanged():
    """The legacy context-less call still targets the default context."""
    rt = make_rt()
    h = rt.register("m", _mm_builder)
    h(jnp.ones((4, 4)), jnp.eye(4))
    h.enable_instrumentation(rate=1.0,
                             collectors={"n": lambda a, k: a[0].shape[0]})
    h(jnp.ones((4, 4)), jnp.eye(4))
    assert h.spec_space().observed["n"]["samples"] == 1
    assert h._snapshot.variant.specialized.instrumented
    h.disable_instrumentation()
    assert not h._snapshot.variant.specialized.instrumented
    rt.shutdown()
