"""Checkpoint manager + data pipeline tests."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import RequestGenerator, SyntheticLM


def _tree():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "b": jnp.ones(4)},
            "opt": {"count": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    t = _tree()
    mgr.save(5, t, extra_meta={"loss": 1.5}, block=True)
    restored, meta = mgr.restore(t)
    np.testing.assert_array_equal(restored["params"]["w"], t["params"]["w"])
    assert meta["step"] == 5 and meta["loss"] == 1.5


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in range(5):
        mgr.save(s, _tree(), block=True)
    assert mgr.all_steps() == [3, 4]


def test_async_save_off_critical_path(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    t0 = time.perf_counter()
    mgr.save(1, _tree())
    submit_time = time.perf_counter() - t0
    mgr.wait()
    assert mgr.all_steps() == [1]
    assert submit_time < 5.0


def test_atomic_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(), block=True)
    entries = [e for e in os.listdir(tmp_path) if e.startswith(".tmp_")]
    assert entries == []


def test_restore_latest_and_specific(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    t = _tree()
    for s in (1, 2, 3):
        t = jax.tree_util.tree_map(lambda x: x + 1, t)
        mgr.save(s, t, block=True)
    _, meta = mgr.restore(t)
    assert meta["step"] == 3
    r1, meta1 = mgr.restore(t, step=1)
    assert meta1["step"] == 1


# -- data ---------------------------------------------------------------------

def test_synthetic_determinism_and_restart():
    ds1 = SyntheticLM(vocab_size=1000, batch=4, seq_len=16, seed=3,
                      prefetch=0)
    b5 = ds1.batch_at(5)
    # restart from checkpointed step: identical stream
    ds2 = SyntheticLM(vocab_size=1000, batch=4, seq_len=16, seed=3,
                      start_step=5, prefetch=0)
    b5b = next(iter(ds2))
    np.testing.assert_array_equal(b5["tokens"], np.asarray(b5b["tokens"]))


def test_labels_are_shifted_tokens():
    ds = SyntheticLM(vocab_size=100, batch=2, seq_len=8, seed=0, prefetch=0)
    b = ds.batch_at(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_zipf_skew():
    ds = SyntheticLM(vocab_size=1000, batch=64, seq_len=64, seed=0,
                     prefetch=0)
    toks = ds.batch_at(0)["tokens"].ravel()
    # Zipf: the most common token should be much more frequent than median
    counts = np.bincount(toks, minlength=1000)
    assert counts.max() > 20 * max(np.median(counts), 1)


def test_request_generator_phases():
    rg = RequestGenerator(seed=1)
    k1 = set(rg.keys(512).tolist())
    rg.shift()
    k2 = set(rg.keys(512).tolist())
    assert len(k1 & k2) < len(k1) * 0.2


def test_request_lengths_distribution():
    rg = RequestGenerator(lengths=(8, 16), length_probs=(0.9, 0.1), seed=0)
    ls = rg.batch_lengths(1000)
    assert (ls == 8).mean() > 0.8
